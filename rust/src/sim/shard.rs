//! Process-sharded batch execution: the engine's `Job`s over a wire.
//!
//! [`super::engine`] scales a sweep across the threads of one process; this
//! layer scales it across *processes* (and, because the protocol is plain
//! line-delimited JSON on stdin/stdout, across hosts behind any pipe-shaped
//! transport).  The design rests on the same fact the in-process engine
//! exploits: a [`Job`] is a pure function of its inputs, so work can be
//! partitioned, duplicated and re-dispatched freely without changing the
//! result (DESIGN.md §12).
//!
//! **Wire format** — one JSON document per `\n`-terminated line
//! ([`crate::util::json::to_compact_string`]).  A job line does *not* carry
//! program bytes or the base DM image; it names the model
//! (`models::resolve` syntax, so `synth:<kind>:<seed>` works with no
//! artifacts dir) and the variant, and the worker hydrates both from its
//! own [`CompileCache`].  Compilation is deterministic, and the line carries
//! the coordinator's program and base-DM fingerprints so a divergent
//! hydration is an explicit error instead of silently wrong logits:
//!
//! ```text
//! > {"type":"job","seq":7,"model":"synth:tiny:3","variant":"v4",
//!    "input":"<hex>","max_instrs":68719476736,"pfp":"<16hex>","dmfp":"<16hex>"}
//! < {"type":"result","seq":7,"output":[-12,33,...],"instrs":9041,"cycles":11213}
//! < {"type":"result","seq":8,"error":"memory fault at pc 0x40: ..."}
//! ```
//!
//! **Failure model** — mirrors the in-process contract ([`run_batch`]):
//! a [`SimError`] travels back as a result line (it stays at its index, as
//! [`SimError::Remote`]); a worker *death* (crash, kill, protocol
//! corruption — the process-level analogue of a worker-thread panic) gets
//! its outstanding jobs re-dispatched to surviving workers, and a job that
//! kills [`POISON_DEATHS`] workers — or the death of every worker — is
//! propagated to the caller as a panic, exactly like a panicking job in the
//! thread pool.  Re-dispatch is idempotent: jobs are pure, duplicate
//! results are byte-identical and the first one wins.  A dead worker slot
//! is additionally *relaunched* in place up to [`RESPAWN_ATTEMPTS`] times
//! (fresh process, fresh hydration cache) — death attribution happens
//! before the respawn, so the poison contract is unchanged.
//!
//! **Determinism** — `run` merges results by submission order (`results[i]`
//! ↔ `descs[i]`), so the output is byte-identical for any worker count,
//! any partition, and any re-dispatch schedule; `tests/shard.rs` holds the
//! differential against the in-process engine.

use std::collections::{HashMap, VecDeque};
use std::io::{BufRead, BufReader, Write};
use std::path::{Path, PathBuf};
use std::process::{Child, ChildStdin, Command, Stdio};
use std::sync::mpsc;
use std::sync::Arc;
use std::time::{Duration, Instant};

use anyhow::{anyhow, bail, ensure, Context, Result};

use super::chaos::{self, WorkerAction};
use super::cpu::{Machine, RemoteKind, RunStats, SimError};
use super::engine::{run_batch, run_job_pooled, Job, JobOutput};
use crate::compiler::{CompileCache, Compiled};
use crate::models;
use crate::sim::Variant;
use crate::util::json::{self, ObjBuilder, Value};

/// A worker death is attributed to every job outstanding on it; a job that
/// accumulates this many attributed deaths is declared poison and
/// propagated as a panic (the process analogue of a panicking thread job).
pub const POISON_DEATHS: u32 = 2;

/// How many times a dead worker slot is relaunched before it is retired
/// for good and its jobs fall back to survivors.  Respawn restores pool
/// capacity after a transient death (OOM kill, node hiccup) without
/// weakening the poison contract: death attribution happens before the
/// respawn, so a job that keeps killing its workers still panics after
/// [`POISON_DEATHS`] deaths.
pub const RESPAWN_ATTEMPTS: u32 = 2;

/// Max jobs kept in flight per worker: deep enough to hide the pipe
/// round-trip behind execution, shallow enough that a death re-dispatches
/// little work.  Public because a shard backend's effective parallelism
/// ([`crate::sim::exec::Caps::parallelism`]) is `workers × PIPELINE`.
pub const PIPELINE: usize = 2;

/// Per-job retry budget (DESIGN.md §16), shared by every *non-death*
/// recovery mechanism: retries of transient ([`RemoteKind::Retryable`])
/// wire errors, straggler duplicate dispatch, and per-job-timeout
/// re-dispatch each consume one unit.  Distinct from the death contract —
/// worker deaths are tracked by [`POISON_DEATHS`] and never charge this
/// budget.  A retryable error arriving with the budget spent surfaces as
/// a *fatal* `retry budget exhausted` [`SimError::Remote`] at the job's
/// index.
pub const JOB_RETRIES: u32 = 3;

/// Base of the exponential backoff between retries of a transient wire
/// error (doubles per consumed retry: 10, 20, 40 ms).  Kept short — a
/// shard worker's transient failures are pipe-scale, not network-scale.
const RETRY_BACKOFF_BASE: Duration = Duration::from_millis(10);

/// Env override (milliseconds) for the per-job timeout after which an
/// outstanding job is speculatively re-dispatched to another worker,
/// charging the [`JOB_RETRIES`] budget.  Without it the timeout equals
/// the batch's [`stall_timeout`] — effectively straggler-only behavior —
/// because a healthy job's duration is workload-dependent and the
/// watchdog budget already bounds it; the override exists for tests and
/// latency-critical deployments that know their job costs.
pub const MARVEL_JOB_TIMEOUT_MS_ENV: &str = "MARVEL_JOB_TIMEOUT_MS";

/// The per-job timeout for a batch: [`MARVEL_JOB_TIMEOUT_MS_ENV`] if set
/// (parse failures fall through to the default — a garbage override must
/// not panic a production pool), else the batch's stall timeout.
pub(crate) fn job_timeout(descs: &[JobDesc]) -> Duration {
    if let Ok(ms) = std::env::var(MARVEL_JOB_TIMEOUT_MS_ENV) {
        if let Ok(ms) = ms.trim().parse::<u64>() {
            if ms > 0 {
                return Duration::from_millis(ms);
            }
        }
        eprintln!(
            "shard: ignoring unparseable {MARVEL_JOB_TIMEOUT_MS_ENV}={ms:?}"
        );
    }
    stall_timeout(descs)
}

/// Floor for the stall backstop (see [`stall_timeout`]).
const STALL_TIMEOUT_MIN: Duration = Duration::from_secs(300);

/// Pessimistic sustained simulation rate used to convert a watchdog budget
/// into wall-clock: the ISS targets ≥100 M instr/s (DESIGN.md §10), so a
/// worker more than an order of magnitude slower is treated as wedged.
const STALL_FLOOR_INSTRS_PER_SEC: u64 = 10_000_000;

/// How long `run` waits for *any* worker event before declaring the pool
/// stalled.  A worker is silent for the whole duration of one job, so the
/// backstop must dominate the longest *legitimate* job: the batch's
/// largest `max_instrs` at a pessimistic simulation rate (a job within its
/// watchdog budget must never panic the pool), floored at
/// [`STALL_TIMEOUT_MIN`] for tiny budgets.
pub(crate) fn stall_timeout(descs: &[JobDesc]) -> Duration {
    let max_instrs = descs.iter().map(|d| d.max_instrs).max().unwrap_or(0);
    STALL_TIMEOUT_MIN
        .max(Duration::from_secs(max_instrs / STALL_FLOOR_INSTRS_PER_SEC + 1))
}

/// Hard cap on one wire message, both directions and both transports
/// (stdio pipes here, TCP frames in [`super::cluster`]).  A peer writing a
/// longer line is treated as corrupted — the read fails with an
/// `oversized frame` error instead of buffering without bound, and the
/// coordinator refuses to *send* a job that could not survive the trip
/// (a structured [`RemoteKind::Fatal`] at the job's index).  Generously
/// above any legitimate message: inputs are KB-scale hex and outputs are
/// logit vectors.
pub const MAX_WIRE_BYTES: usize = 8 * 1024 * 1024;

/// Read one `\n`-terminated line, enforcing [`MAX_WIRE_BYTES`] (`cap`):
/// `Ok(None)` on clean EOF, `Ok(Some(line))` without the terminator, and
/// an `InvalidData` error on an oversized or non-UTF-8 line — the caller
/// treats either as peer corruption (a death), never as a result.
///
/// The final line of a stream may arrive unterminated (a peer that died
/// mid-write); it is returned as-is and will fail parsing downstream if
/// truncated.
pub fn read_line_capped(
    r: &mut impl BufRead,
    cap: usize,
) -> std::io::Result<Option<String>> {
    use std::io::{Error, ErrorKind};
    fn utf8(buf: Vec<u8>) -> std::io::Result<Option<String>> {
        match String::from_utf8(buf) {
            Ok(s) => Ok(Some(s)),
            Err(e) => Err(Error::new(
                ErrorKind::InvalidData,
                format!("non-UTF-8 frame: {e}"),
            )),
        }
    }
    let mut buf: Vec<u8> = Vec::new();
    loop {
        let chunk = r.fill_buf()?;
        if chunk.is_empty() {
            return if buf.is_empty() { Ok(None) } else { utf8(buf) };
        }
        let newline = chunk.iter().position(|&b| b == b'\n');
        let take = newline.unwrap_or(chunk.len());
        if buf.len() + take > cap {
            let consumed = take + usize::from(newline.is_some());
            r.consume(consumed);
            return Err(Error::new(
                ErrorKind::InvalidData,
                format!(
                    "oversized frame: line exceeds the {cap}-byte wire cap"
                ),
            ));
        }
        buf.extend_from_slice(&chunk[..take]);
        let consumed = take + usize::from(newline.is_some());
        r.consume(consumed);
        if newline.is_some() {
            return utf8(buf);
        }
    }
}

// ---------------------------------------------------------------------------
// Wire format
// ---------------------------------------------------------------------------

/// FNV-1a over a byte slice — the fingerprint the wire uses for base-DM
/// images (the program side uses [`super::Program::fingerprint`]; one
/// shared definition in `util`, since these hashes are compared across
/// processes).
pub use crate::util::fnv1a;

/// Lowercase hex encoding (input images on the wire).
pub fn to_hex(bytes: &[u8]) -> String {
    const HEX: &[u8; 16] = b"0123456789abcdef";
    let mut s = String::with_capacity(bytes.len() * 2);
    for &b in bytes {
        s.push(HEX[(b >> 4) as usize] as char);
        s.push(HEX[(b & 0xf) as usize] as char);
    }
    s
}

/// Inverse of [`to_hex`].
pub fn from_hex(s: &str) -> Result<Vec<u8>> {
    ensure!(s.len() % 2 == 0, "odd-length hex string ({} chars)", s.len());
    (0..s.len() / 2)
        .map(|i| {
            u8::from_str_radix(&s[2 * i..2 * i + 2], 16)
                .map_err(|e| anyhow!("bad hex at byte {i}: {e}"))
        })
        .collect()
}

/// One simulation run described *by reference*: everything a worker needs
/// to rebuild the corresponding [`Job`] from its own compile cache.  The
/// only bulk payload is the per-run input image.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct JobDesc {
    /// Model name in [`models::resolve`] syntax (artifact or `synth:`).
    pub model: String,
    /// Variant name (`v0`..`v4`).
    pub variant: String,
    /// Packed int8 input image ([`crate::compiler::pack_input`]).
    pub input: Vec<u8>,
    /// Watchdog budget (values above 2^53 are clamped on the wire — the
    /// JSON number model — which no reachable run can tell apart).
    pub max_instrs: u64,
    /// [`super::Program::fingerprint`] of the coordinator's compilation;
    /// `0` skips the hydration cross-check (hand-built descriptions).
    pub program_fp: u64,
    /// [`fnv1a`] of the coordinator's `Compiled::base_dm`; `0` skips.
    pub base_dm_fp: u64,
}

/// Describe one inference on a coordinator-side compilation, fingerprints
/// included — the standard way to build a [`JobDesc`].
pub fn desc_for(
    model: &str,
    c: &Compiled,
    input: &[u8],
    max_instrs: u64,
) -> JobDesc {
    JobDesc {
        model: model.to_string(),
        variant: c.variant().name.to_string(),
        input: input.to_vec(),
        max_instrs,
        program_fp: c.program.fingerprint(),
        base_dm_fp: c.base_dm_fp(),
    }
}

/// A parsed protocol line (both directions share the enum).
#[derive(Clone, Debug, PartialEq)]
pub enum Msg {
    /// Worker → coordinator: handshake after startup.
    Ready,
    /// Coordinator → worker: one job to run.
    Job { seq: u64, desc: JobDesc },
    /// Worker → coordinator: outcome of job `seq`.
    Done { seq: u64, result: Result<JobOutput, String> },
}

fn fp_hex(fp: u64) -> String {
    format!("{fp:016x}")
}

/// Serialize the handshake line.
pub fn encode_ready() -> String {
    json::to_compact_string(
        &ObjBuilder::new()
            .set("type", "ready")
            .set("version", crate::version())
            .build(),
    )
}

/// Serialize a job line.
pub fn encode_job(seq: u64, d: &JobDesc) -> String {
    json::to_compact_string(
        &ObjBuilder::new()
            .set("type", "job")
            .set("seq", seq)
            .set("model", d.model.as_str())
            .set("variant", d.variant.as_str())
            .set("input", to_hex(&d.input))
            .set("max_instrs", d.max_instrs.min(1 << 53))
            .set("pfp", fp_hex(d.program_fp))
            .set("dmfp", fp_hex(d.base_dm_fp))
            .build(),
    )
}

/// Serialize a result line.
pub fn encode_result(seq: u64, r: &Result<JobOutput, String>) -> String {
    let b = ObjBuilder::new().set("type", "result").set("seq", seq);
    let b = match r {
        Ok(o) => b
            .set(
                "output",
                o.output.iter().map(|&v| i64::from(v)).collect::<Vec<i64>>(),
            )
            .set("instrs", o.stats.instrs)
            .set("cycles", o.stats.cycles),
        Err(e) => b.set("error", e.as_str()),
    };
    json::to_compact_string(&b.build())
}

fn parse_fp(v: &Value, key: &str) -> Result<u64> {
    let s = v.get(key)?.as_str()?;
    u64::from_str_radix(s, 16)
        .map_err(|e| anyhow!("bad fingerprint {key}={s:?}: {e}"))
}

/// Parse one protocol line.
pub fn parse_line(line: &str) -> Result<Msg> {
    let v = json::parse(line)?;
    match v.get("type")?.as_str()? {
        "ready" => Ok(Msg::Ready),
        "job" => Ok(Msg::Job {
            seq: v.get("seq")?.as_u64()?,
            desc: JobDesc {
                model: v.get("model")?.as_str()?.to_string(),
                variant: v.get("variant")?.as_str()?.to_string(),
                input: from_hex(v.get("input")?.as_str()?)?,
                max_instrs: v.get("max_instrs")?.as_u64()?,
                program_fp: parse_fp(&v, "pfp")?,
                base_dm_fp: parse_fp(&v, "dmfp")?,
            },
        }),
        "result" => {
            let seq = v.get("seq")?.as_u64()?;
            let result = match v.get_opt("error") {
                Some(e) => Err(e.as_str()?.to_string()),
                None => {
                    let output = v
                        .get("output")?
                        .as_arr()?
                        .iter()
                        .map(|x| {
                            let n = x.as_i64()?;
                            i32::try_from(n)
                                .map_err(|_| anyhow!("logit {n} exceeds i32"))
                        })
                        .collect::<Result<Vec<i32>>>()?;
                    Ok(JobOutput {
                        output,
                        stats: RunStats {
                            instrs: v.get("instrs")?.as_u64()?,
                            cycles: v.get("cycles")?.as_u64()?,
                        },
                    })
                }
            };
            Ok(Msg::Done { seq, result })
        }
        other => bail!("unknown message type {other:?}"),
    }
}

// ---------------------------------------------------------------------------
// Worker side: hydrate a JobDesc from the local compile cache and run it
// ---------------------------------------------------------------------------

/// Per-process model/compilation store a worker hydrates job descriptions
/// from.  Every `(model, variant)` resolves and compiles exactly once; the
/// resulting [`Compiled`] (program + base DM image) is what the wire
/// deliberately does not ship.
pub struct Hydrator {
    artifacts: PathBuf,
    cache: CompileCache,
    /// `(model, variant)` → compiled unit + its output element count.
    units: HashMap<(String, String), (Arc<Compiled>, usize)>,
}

impl Hydrator {
    pub fn new(artifacts: &Path) -> Hydrator {
        Hydrator {
            artifacts: artifacts.to_path_buf(),
            cache: CompileCache::new(),
            units: HashMap::new(),
        }
    }

    /// Resolve + compile (memoized) the unit a description references.
    pub fn hydrate(
        &mut self,
        model: &str,
        variant: &str,
    ) -> Result<(Arc<Compiled>, usize)> {
        let key = (model.to_string(), variant.to_string());
        if let Some((c, n)) = self.units.get(&key) {
            return Ok((Arc::clone(c), *n));
        }
        let spec = models::resolve(&self.artifacts, model)
            .with_context(|| format!("hydrating model {model}"))?;
        let v = Variant::by_name(variant)
            .with_context(|| format!("unknown variant {variant:?}"))?;
        let c = self
            .cache
            .get_or_compile(&spec, v)
            .with_context(|| format!("compiling {model} for {variant}"))?;
        let n = spec.output_elems();
        self.units.insert(key, (Arc::clone(&c), n));
        Ok((c, n))
    }

    /// Hydrate + cross-check + execute one description on the pooled
    /// machine.  Fingerprint mismatches (coordinator and worker compiled
    /// different programs) are an error, not silent divergence.
    pub fn run_desc(
        &mut self,
        pool: &mut Option<Machine>,
        desc: &JobDesc,
    ) -> Result<JobOutput> {
        let (c, out_elems) = self.hydrate(&desc.model, &desc.variant)?;
        check_fingerprints(desc, &c)?;
        let job = job_of(&c, out_elems, &desc.input, desc.max_instrs);
        run_job_pooled(pool, &job).map_err(|e| anyhow!("{e}"))
    }
}

pub(crate) fn check_fingerprints(desc: &JobDesc, c: &Compiled) -> Result<()> {
    if desc.program_fp != 0 {
        let got = c.program.fingerprint();
        ensure!(
            got == desc.program_fp,
            "program fingerprint mismatch for {} on {}: coordinator {:016x}, \
             worker {got:016x} (divergent hydration)",
            desc.model,
            desc.variant,
            desc.program_fp
        );
    }
    if desc.base_dm_fp != 0 {
        let got = c.base_dm_fp();
        ensure!(
            got == desc.base_dm_fp,
            "base-DM fingerprint mismatch for {} on {}: coordinator {:016x}, \
             worker {got:016x}",
            desc.model,
            desc.variant,
            desc.base_dm_fp
        );
    }
    Ok(())
}

/// The engine [`Job`] a hydrated description denotes (the wire-side twin of
/// [`crate::compiler::make_job`], which takes the spec the worker folded
/// into `out_elems` at hydration).  Also the job builder of
/// [`crate::sim::exec::LocalExec`]'s hydrated path.
pub(crate) fn job_of<'a>(
    c: &'a Compiled,
    out_elems: usize,
    input: &'a [u8],
    max_instrs: u64,
) -> Job<'a> {
    Job {
        program: Arc::clone(&c.program),
        dm_size: c.plan.dm_size as usize,
        base_image: Some(&c.base_dm),
        preload: Vec::new(),
        input: (c.plan.input_addr, input),
        output: (c.plan.output_addr, out_elems),
        max_instrs,
    }
}

/// Chaos state shared by every session of one worker process.  The pipe
/// worker has exactly one session so the sharing is trivial; the cluster
/// daemon serves many concurrent connections from one process, and fire
/// counts must be process-wide — otherwise a one-shot `kill@N` would
/// re-fire in the replacement session after every reconnect and compound
/// into a spurious poison panic.
pub type SharedChaos = Arc<std::sync::Mutex<Option<chaos::WorkerChaos>>>;

/// Build the process-wide chaos state from `MARVEL_CHAOS`.
pub fn shared_chaos_from_env() -> Result<SharedChaos> {
    Ok(Arc::new(std::sync::Mutex::new(chaos::WorkerChaos::from_env()?)))
}

/// What a handled job asks the transport to do.  The job-handling core is
/// transport-agnostic; only "dying" differs — a pipe worker dies with its
/// process (`exit(17)`), a socket session dies by closing its connection
/// (the daemon process survives, so the coordinator can re-dial).
pub enum JobReply {
    /// Write these wire payloads in order (one line = one message; chaos
    /// `Dup` yields two copies, `Corrupt` an unparseable line).
    Lines(Vec<String>),
    /// Chaos-injected death: stop without replying.
    Die,
}

/// The transport-agnostic worker session core: hydrate-and-run job
/// descriptions against a per-session compile cache and pooled machine,
/// with worker-site chaos applied per wire seq.  Shared by the pipe
/// worker ([`worker_loop`]) and the cluster daemon's per-connection
/// sessions ([`super::cluster`]).
pub struct WorkerCore {
    hyd: Hydrator,
    pool: Option<Machine>,
    chaos: SharedChaos,
}

impl WorkerCore {
    pub fn new(artifacts: &Path, chaos: SharedChaos) -> WorkerCore {
        WorkerCore { hyd: Hydrator::new(artifacts), pool: None, chaos }
    }

    /// Handle one job message: apply chaos actions, run the description,
    /// and return the result line(s) to write.  An outgoing line past
    /// [`MAX_WIRE_BYTES`] is replaced by a structured fatal error result
    /// at the job's seq — the peer-side mirror of the coordinator's
    /// pre-send cap.
    pub fn handle_job(&mut self, seq: u64, desc: &JobDesc) -> JobReply {
        let mut injected_err: Option<String> = None;
        let mut corrupt = false;
        let mut dup = false;
        let actions = self
            .chaos
            .lock()
            .expect("chaos state poisoned")
            .as_mut()
            .map(|ch| ch.actions(seq))
            .unwrap_or_default();
        for action in actions {
            match action {
                WorkerAction::Delay(d) => std::thread::sleep(d),
                WorkerAction::Kill => return JobReply::Die,
                WorkerAction::Corrupt => corrupt = true,
                WorkerAction::ErrorResult(msg) => injected_err = Some(msg),
                WorkerAction::Dup => dup = true,
            }
        }
        if corrupt {
            // A line that cannot parse: the coordinator treats the peer
            // as corrupted and kills it (a death, not an error result),
            // so nothing else is worth writing.
            return JobReply::Lines(vec!["{\"chaos\":corrupted".to_string()]);
        }
        let result = match injected_err {
            Some(msg) => Err(msg),
            None => self
                .hyd
                .run_desc(&mut self.pool, desc)
                .map_err(|e| format!("{e:#}")),
        };
        let mut line = encode_result(seq, &result);
        if line.len() > MAX_WIRE_BYTES {
            line = encode_result(
                seq,
                &Err(format!(
                    "oversized result frame ({} bytes exceeds the \
                     {MAX_WIRE_BYTES}-byte wire cap)",
                    line.len()
                )),
            );
        }
        let mut lines = vec![line];
        if dup {
            lines.push(lines[0].clone());
        }
        JobReply::Lines(lines)
    }
}

/// The `marvel shard-worker` body: read job lines, stream result lines
/// back incrementally (one write + flush per job, so the coordinator sees
/// results as they complete, not at batch end).  Returns on EOF.  A panic
/// (a bug class, not a [`SimError`]) kills the process — which is exactly
/// the event the coordinator's death handling translates back into the
/// in-process panic contract.
///
/// With `MARVEL_CHAOS` set (the coordinator writes it per incarnation —
/// see [`ShardPool`]) the worker applies the plan's worker-site faults to
/// the jobs it handles, keyed on wire seq: delay before replying, die
/// without replying, write a corrupted line, reply with a transient
/// error, or write the result twice (DESIGN.md §16).  Job handling lives
/// in the transport-agnostic [`WorkerCore`]; this function is the pipe
/// binding (capped line reads, chaos death = process exit).
pub fn worker_loop(
    artifacts: &Path,
    mut input: impl BufRead,
    mut out: impl Write,
) -> Result<()> {
    let mut core = WorkerCore::new(artifacts, shared_chaos_from_env()?);
    writeln!(out, "{}", encode_ready())?;
    out.flush()?;
    loop {
        let line = match read_line_capped(&mut input, MAX_WIRE_BYTES) {
            Ok(None) => return Ok(()),
            Ok(Some(l)) => l,
            Err(e) => return Err(e).context("reading job line"),
        };
        if line.trim().is_empty() {
            continue;
        }
        match parse_line(&line)? {
            Msg::Job { seq, desc } => match core.handle_job(seq, &desc) {
                // Injected death: exit without replying — the
                // coordinator's reader sees EOF, exactly like a crash.
                JobReply::Die => std::process::exit(17),
                JobReply::Lines(lines) => {
                    for l in lines {
                        writeln!(out, "{l}")?;
                    }
                    out.flush()?;
                }
            },
            Msg::Ready => {}
            Msg::Done { .. } => bail!("unexpected result message on worker stdin"),
        }
    }
}

/// Run descriptions in-process: hydrate everything locally and hand the
/// batch to the thread engine.  This is the single-process twin the
/// differential tests (and `marvel shard-sweep --check`) compare a sharded
/// run against; per-description hydration failures stay at their index as
/// [`SimError::Remote`], mirroring the pool.
pub fn run_descs_local(
    artifacts: &Path,
    descs: &[JobDesc],
    threads: usize,
) -> Vec<Result<JobOutput, SimError>> {
    let mut hyd = Hydrator::new(artifacts);
    let units: Vec<Result<(Arc<Compiled>, usize), String>> = descs
        .iter()
        .map(|d| {
            let u = hyd.hydrate(&d.model, &d.variant).map_err(|e| format!("{e:#}"))?;
            check_fingerprints(d, &u.0).map_err(|e| format!("{e:#}"))?;
            Ok(u)
        })
        .collect();
    let jobs: Vec<Job<'_>> = units
        .iter()
        .zip(descs)
        .filter_map(|(u, d)| {
            let (c, n) = u.as_ref().ok()?;
            Some(job_of(c, *n, &d.input, d.max_instrs))
        })
        .collect();
    let ran = run_batch(&jobs, threads);
    drop(jobs); // release the borrows of `units` before consuming it
    let mut ran = ran.into_iter();
    units
        .into_iter()
        .map(|u| match u {
            Ok(_) => ran.next().expect("one result per hydrated job"),
            Err(msg) => Err(SimError::remote(msg)),
        })
        .collect()
}

// ---------------------------------------------------------------------------
// Coordinator side: the shard pool
// ---------------------------------------------------------------------------

/// How to launch one worker process.
#[derive(Clone, Debug, Default)]
pub struct WorkerCmd {
    pub program: PathBuf,
    pub args: Vec<String>,
    /// Extra environment for the child (on top of the inherited
    /// environment).  `MARVEL_CHAOS` set here is the per-pool way to hand
    /// workers a fault plan without mutating the coordinator's own
    /// environment — the pool re-writes it per incarnation either way
    /// (see [`ShardPool::spawn_worker`]).
    pub envs: Vec<(String, String)>,
}

impl WorkerCmd {
    /// The standard worker: this very binary, `marvel shard-worker`.
    pub fn current_exe(artifacts: &Path) -> Result<WorkerCmd> {
        Ok(WorkerCmd {
            program: std::env::current_exe()
                .context("locating the marvel binary for shard workers")?,
            args: vec![
                "shard-worker".to_string(),
                "--artifacts".to_string(),
                artifacts.display().to_string(),
            ],
            envs: Vec::new(),
        })
    }

    /// The chaos plan this command would hand its workers: an explicit
    /// `envs` entry wins over the coordinator's inherited `MARVEL_CHAOS`.
    fn chaos_plan(&self) -> Result<Option<chaos::FaultPlan>> {
        for (k, v) in &self.envs {
            if k == chaos::MARVEL_CHAOS_ENV {
                let plan = chaos::FaultPlan::parse(v).with_context(|| {
                    format!("parsing worker {}={v:?}", chaos::MARVEL_CHAOS_ENV)
                })?;
                return Ok(Some(plan));
            }
        }
        chaos::FaultPlan::from_env()
    }
}

enum Event {
    Msg { worker: usize, gen: u64, msg: Msg },
    Dead { worker: usize, gen: u64, reason: String },
}

/// One result slot per submitted job (`None` = not yet merged).
type Slots = [Option<Result<JobOutput, SimError>>];

struct Worker {
    child: Child,
    stdin: Option<ChildStdin>,
    alive: bool,
    /// Incarnation counter for this slot: events from a replaced process
    /// (its reader thread races the respawn) carry the old generation and
    /// must not be charged to the new one.
    gen: u64,
    /// Job indices (current `run` call) dispatched here and not yet done,
    /// with dispatch time — the per-job timeout clock ([`job_timeout`]).
    outstanding: HashMap<usize, Instant>,
}

/// A pool of worker processes executing [`JobDesc`] batches with
/// submission-ordered merge (see the module docs for the failure model).
/// Workers stay warm across `run` calls, so a sweep's later batches reuse
/// every compilation the first one hydrated.  A worker slot whose process
/// dies is relaunched in place up to [`RESPAWN_ATTEMPTS`] times (its jobs
/// are requeued either way — the respawn only restores capacity).
pub struct ShardPool {
    workers: Vec<Worker>,
    rx: mpsc::Receiver<Event>,
    tx: mpsc::Sender<Event>,
    cmd: WorkerCmd,
    next_seq: u64,
    gen_counter: u64,
    /// Remaining relaunches per worker slot.
    respawns_left: Vec<u32>,
    respawns_used: u32,
    /// `(full, stripped)` rendered chaos plans when the command carries
    /// one: the *first* process spawned gets `full` (death faults
    /// included); every later incarnation — sibling slots and respawns —
    /// gets `stripped` ([`chaos::FaultPlan::strip_one_shot`]), so each
    /// injected death fires exactly once pool-wide and can never compound
    /// into a spurious [`POISON_DEATHS`] panic.
    chaos_plans: Option<(String, String)>,
    chaos_primary_spawned: bool,
}

impl ShardPool {
    /// Spawn `n` worker processes (stderr passes through to the caller's).
    pub fn spawn(cmd: &WorkerCmd, n: usize) -> Result<ShardPool> {
        ensure!(n > 0, "shard pool needs at least one worker");
        let chaos_plans = cmd.chaos_plan()?.and_then(|plan| {
            if plan.worker_faults().next().is_none() {
                return None; // exec-site-only plan: workers run clean
            }
            Some((plan.to_string(), plan.strip_one_shot().to_string()))
        });
        let (tx, rx) = mpsc::channel();
        let mut pool = ShardPool {
            workers: Vec::new(),
            rx,
            tx,
            cmd: cmd.clone(),
            next_seq: 0,
            gen_counter: n as u64,
            respawns_left: vec![RESPAWN_ATTEMPTS; n],
            respawns_used: 0,
            chaos_plans,
            chaos_primary_spawned: false,
        };
        for worker in 0..n {
            let w = pool.spawn_one(worker, worker as u64)?;
            pool.workers.push(w);
        }
        Ok(pool)
    }

    /// Spawn an incarnation for slot `worker`, handing it this pool's
    /// chaos plan (full for the first process ever spawned, stripped for
    /// everyone after — see [`ShardPool::chaos_plans`]).
    fn spawn_one(&mut self, worker: usize, gen: u64) -> Result<Worker> {
        let plan = match &self.chaos_plans {
            None => None,
            Some((full, stripped)) => {
                if self.chaos_primary_spawned {
                    Some(stripped.as_str())
                } else {
                    Some(full.as_str())
                }
            }
        };
        let w = Self::spawn_worker(&self.cmd, worker, gen, &self.tx, plan)?;
        self.chaos_primary_spawned = true;
        Ok(w)
    }

    /// Spawn one worker process + its stdout reader thread for slot
    /// `worker`, incarnation `gen`.  `chaos` is the exact `MARVEL_CHAOS`
    /// value for this incarnation (the inherited variable is always
    /// cleared first — per-incarnation stripping must win over whatever
    /// the coordinator's environment says).
    fn spawn_worker(
        cmd: &WorkerCmd,
        worker: usize,
        gen: u64,
        tx: &mpsc::Sender<Event>,
        chaos: Option<&str>,
    ) -> Result<Worker> {
        let mut command = Command::new(&cmd.program);
        command.args(&cmd.args);
        for (k, v) in &cmd.envs {
            command.env(k, v);
        }
        command.env_remove(chaos::MARVEL_CHAOS_ENV);
        if let Some(plan) = chaos {
            if !plan.is_empty() {
                command.env(chaos::MARVEL_CHAOS_ENV, plan);
            }
        }
        let mut child = command
            .stdin(Stdio::piped())
            .stdout(Stdio::piped())
            .spawn()
            .with_context(|| {
                format!("spawning shard worker {}", cmd.program.display())
            })?;
        let stdin = child.stdin.take();
        let stdout = child.stdout.take().expect("piped stdout");
        let tx = tx.clone();
        std::thread::spawn(move || {
            let mut rd = BufReader::new(stdout);
            loop {
                // Capped read: a worker streaming an over-cap or non-UTF-8
                // line is corrupted, not trusted to buffer without bound.
                let event = match read_line_capped(&mut rd, MAX_WIRE_BYTES) {
                    Ok(None) => {
                        let _ = tx.send(Event::Dead {
                            worker,
                            gen,
                            reason: "eof".into(),
                        });
                        return;
                    }
                    Ok(l) if l.as_deref().is_some_and(|l| l.trim().is_empty()) => {
                        continue;
                    }
                    Ok(Some(l)) => match parse_line(&l) {
                        Ok(msg) => Event::Msg { worker, gen, msg },
                        Err(e) => {
                            let _ = tx.send(Event::Dead {
                                worker,
                                gen,
                                reason: format!("protocol error: {e:#}"),
                            });
                            return;
                        }
                    },
                    Err(e) => {
                        let _ = tx.send(Event::Dead {
                            worker,
                            gen,
                            reason: format!("read error: {e}"),
                        });
                        return;
                    }
                };
                if tx.send(event).is_err() {
                    return;
                }
            }
        });
        Ok(Worker {
            child,
            stdin,
            alive: true,
            gen,
            outstanding: HashMap::new(),
        })
    }

    /// Relaunch a dead worker slot, consuming one unit of its
    /// [`RESPAWN_ATTEMPTS`] budget per spawn attempt (a failed spawn —
    /// transient fork/exec errors — retries until the budget is spent, so
    /// a slot is only retired with its budget exhausted).  The old
    /// incarnation was already killed/requeued; a fresh process (new
    /// generation) takes over the slot and is immediately dispatchable.
    fn try_respawn(&mut self, worker: usize) {
        while self.respawns_left[worker] > 0 {
            self.respawns_left[worker] -= 1;
            self.gen_counter += 1;
            let gen = self.gen_counter;
            match self.spawn_one(worker, gen) {
                Ok(w) => {
                    self.respawns_used += 1;
                    eprintln!(
                        "shard worker {worker} respawned ({} attempts left)",
                        self.respawns_left[worker]
                    );
                    self.workers[worker] = w;
                    return;
                }
                Err(e) => eprintln!(
                    "shard worker {worker} respawn failed ({} attempts \
                     left): {e:#}",
                    self.respawns_left[worker]
                ),
            }
        }
    }

    /// Live worker count (before a run, this is the spawn count).
    pub fn live_workers(&self) -> usize {
        self.workers.iter().filter(|w| w.alive).count()
    }

    /// How many dead workers have been relaunched over the pool's
    /// lifetime (observability + the respawn tests).
    pub fn respawns_used(&self) -> u32 {
        self.respawns_used
    }

    /// Execute a batch across the pool.  `results[i]` corresponds to
    /// `descs[i]`, byte-identical to [`run_descs_local`] for any worker
    /// count or re-dispatch schedule.  Panics if a poison job kills
    /// [`POISON_DEATHS`] workers or every worker dies — the process-level
    /// mirror of [`run_batch`]'s panic propagation.
    ///
    /// **Recovery budgets** (DESIGN.md §16): a job answered with a
    /// *retryable* wire error ([`RemoteKind::classify`]) is requeued with
    /// exponential backoff; straggler duplicates and per-job-timeout
    /// re-dispatch draw from the same [`JOB_RETRIES`] budget.  A
    /// retryable error past budget surfaces as a fatal
    /// `retry budget exhausted` [`SimError::Remote`] at the job's index.
    /// Worker deaths stay on the separate [`POISON_DEATHS`] contract.
    pub fn run(&mut self, descs: &[JobDesc]) -> Vec<Result<JobOutput, SimError>> {
        let n = descs.len();
        let base = self.next_seq;
        self.next_seq += n as u64;
        let stall = stall_timeout(descs);
        let per_job = job_timeout(descs);
        // Per-run bookkeeping: stale outstanding entries are duplicates
        // from a previous batch whose first copy already won; their late
        // results are discarded below by the seq-range guard, so the slots
        // are free again.
        for w in &mut self.workers {
            w.outstanding.clear();
        }
        let mut results: Vec<Option<Result<JobOutput, SimError>>> =
            (0..n).map(|_| None).collect();
        let mut done = 0usize;
        // Pre-send wire cap: a job whose encoded line cannot travel the
        // wire fails at its own index with a structured fatal error — it
        // must never reach a worker, where the oversized line would read
        // as corruption and kill the process (a death the job did not
        // deserve to be charged with).
        for (i, d) in descs.iter().enumerate() {
            let wire = encode_job(base + i as u64, d).len();
            if wire > MAX_WIRE_BYTES {
                results[i] = Some(Err(SimError::Remote {
                    msg: format!(
                        "oversized job frame ({wire} bytes exceeds the \
                         {MAX_WIRE_BYTES}-byte wire cap)"
                    ),
                    kind: RemoteKind::Fatal,
                }));
                done += 1;
            }
        }
        let mut queue: VecDeque<usize> =
            (0..n).filter(|&i| results[i].is_none()).collect();
        // Which workers job i has been dispatched to (caps duplicate
        // dispatch at one per worker) and how many worker deaths it has
        // been implicated in.
        let mut dispatched: Vec<Vec<usize>> = vec![Vec::new(); n];
        let mut deaths: Vec<u32> = vec![0; n];
        // Units of the shared JOB_RETRIES budget each job has consumed,
        // and the earliest instant a backoff allows its next dispatch.
        let mut retries: Vec<u32> = vec![0; n];
        let mut backoff: Vec<Option<Instant>> = vec![None; n];
        let mut last_event = Instant::now();

        while done < n {
            // Fill pipelines from the queue; once the queue drains,
            // speculatively re-dispatch outstanding work to idle workers
            // (straggler mitigation: first result wins, duplicates are
            // byte-identical by purity).
            self.dispatch(
                descs, base, &results, &mut queue, &mut dispatched,
                &mut deaths, &mut retries, &backoff,
            );
            if self.live_workers() == 0 {
                panic!(
                    "shard pool: all workers died with {} of {n} jobs \
                     unfinished",
                    n - done
                );
            }
            // Sleep until the next actionable instant: a worker event,
            // the stall backstop, a backoff expiry (a requeued job
            // becomes dispatchable) or a per-job timeout (an outstanding
            // job becomes a forced straggler).
            let now = Instant::now();
            let mut wait = (last_event + stall).saturating_duration_since(now);
            for b in backoff.iter().flatten() {
                wait = wait.min(b.saturating_duration_since(now));
            }
            for w in self.workers.iter().filter(|w| w.alive) {
                for t0 in w.outstanding.values() {
                    wait = wait.min(
                        (*t0 + per_job).saturating_duration_since(now),
                    );
                }
            }
            let event = match self.rx.recv_timeout(wait.max(Duration::from_millis(1))) {
                Ok(e) => {
                    last_event = Instant::now();
                    e
                }
                Err(_) => {
                    if last_event.elapsed() >= stall {
                        panic!(
                            "shard pool stalled: no worker event within \
                             {stall:?} ({} of {n} jobs unfinished)",
                            n - done
                        );
                    }
                    // Per-job timeouts: requeue every over-deadline
                    // outstanding job (budget allowing) so dispatch sends
                    // a duplicate to a different worker; the original
                    // stays outstanding — first result wins — and its
                    // clock resets so one slow job charges the budget
                    // once per timeout period, not once per wakeup.
                    let now = Instant::now();
                    for w in self.workers.iter_mut().filter(|w| w.alive) {
                        for (&i, t0) in w.outstanding.iter_mut() {
                            if now.saturating_duration_since(*t0) < per_job
                                || results[i].is_some()
                                || retries[i] >= JOB_RETRIES
                                || queue.contains(&i)
                            {
                                continue;
                            }
                            retries[i] += 1;
                            *t0 = now;
                            queue.push_back(i);
                            eprintln!(
                                "shard job {i} timed out after {per_job:?}; \
                                 re-dispatching ({} of {JOB_RETRIES} budget \
                                 used)",
                                retries[i]
                            );
                        }
                    }
                    continue;
                }
            };
            match event {
                Event::Msg { msg: Msg::Ready, .. } => {}
                Event::Msg { worker, gen, msg: Msg::Done { seq, result } } => {
                    let Some(i) = seq.checked_sub(base).map(|d| d as usize)
                    else {
                        continue; // stale: previous run
                    };
                    if i >= n {
                        continue;
                    }
                    // A result is mergeable whatever its generation (jobs
                    // are pure — a late result from a replaced process is
                    // byte-identical), but only the current incarnation's
                    // pipeline bookkeeping may be touched.
                    if gen == self.workers[worker].gen {
                        self.workers[worker].outstanding.remove(&i);
                    }
                    if results[i].is_some() {
                        continue; // a duplicate's first copy already won
                    }
                    match result {
                        Ok(o) => {
                            results[i] = Some(Ok(o));
                            done += 1;
                        }
                        Err(msg) => {
                            let kind = RemoteKind::classify(&msg);
                            if kind == RemoteKind::Retryable
                                && retries[i] < JOB_RETRIES
                            {
                                // Transient wire error within budget:
                                // requeue with exponential backoff.
                                retries[i] += 1;
                                backoff[i] = Some(
                                    Instant::now()
                                        + RETRY_BACKOFF_BASE
                                            * (1 << (retries[i] - 1).min(6)),
                                );
                                if !queue.contains(&i) {
                                    queue.push_back(i);
                                }
                                eprintln!(
                                    "shard job {i} transient failure \
                                     (retry {} of {JOB_RETRIES}): {msg}",
                                    retries[i]
                                );
                            } else {
                                let err = if kind == RemoteKind::Retryable {
                                    SimError::Remote {
                                        msg: format!(
                                            "retry budget exhausted after \
                                             {} attempts: {msg}",
                                            retries[i] + 1
                                        ),
                                        kind: RemoteKind::Fatal,
                                    }
                                } else {
                                    SimError::Remote { msg, kind }
                                };
                                results[i] = Some(Err(err));
                                done += 1;
                            }
                        }
                    }
                }
                Event::Msg { worker, gen, msg: Msg::Job { .. } } => {
                    if gen != self.workers[worker].gen {
                        continue; // a replaced process's last gasp
                    }
                    // A worker must never send jobs; treat as corruption.
                    self.kill_worker(worker, "sent a job message");
                    Self::requeue(
                        &mut self.workers[worker],
                        &results,
                        &mut queue,
                        &mut deaths,
                        descs,
                    );
                    self.try_respawn(worker);
                }
                Event::Dead { worker, gen, reason } => {
                    if gen != self.workers[worker].gen
                        || !self.workers[worker].alive
                    {
                        continue; // already handled (or a replaced process)
                    }
                    self.kill_worker(worker, &reason);
                    Self::requeue(
                        &mut self.workers[worker],
                        &results,
                        &mut queue,
                        &mut deaths,
                        descs,
                    );
                    self.try_respawn(worker);
                }
            }
        }
        results
            .into_iter()
            .map(|r| r.expect("merge filled every slot"))
            .collect()
    }

    /// Send queued jobs to live workers with pipeline capacity; with an
    /// empty queue, duplicate outstanding jobs onto idle workers
    /// (charging the straggler duplicate to the job's [`JOB_RETRIES`]
    /// budget).  Jobs whose retry backoff has not expired stay queued.
    #[allow(clippy::too_many_arguments)] // one call site; the run-loop state
    fn dispatch(
        &mut self,
        descs: &[JobDesc],
        base: u64,
        results: &Slots,
        queue: &mut VecDeque<usize>,
        dispatched: &mut [Vec<usize>],
        deaths: &mut [u32],
        retries: &mut [u32],
        backoff: &[Option<Instant>],
    ) {
        let now = Instant::now();
        loop {
            let Some(w) = self
                .workers
                .iter()
                .enumerate()
                .filter(|(_, wk)| wk.alive && wk.outstanding.len() < PIPELINE)
                .min_by_key(|(_, wk)| wk.outstanding.len())
                .map(|(i, _)| i)
            else {
                return;
            };
            // Drop anything that completed while queued (a duplicate's
            // first copy finished); skip — but keep — jobs still backing
            // off.
            while queue.front().is_some_and(|&i| results[i].is_some()) {
                queue.pop_front();
            }
            let eligible = queue
                .iter()
                .position(|&i| {
                    results[i].is_none()
                        && backoff[i].is_none_or(|b| b <= now)
                })
                .and_then(|p| queue.remove(p));
            let i = match eligible {
                Some(i) => i,
                None => {
                    if !queue.is_empty() {
                        return; // everything queued is backing off
                    }
                    // Straggler re-dispatch: only for fully idle workers,
                    // onto the least-duplicated outstanding job this worker
                    // has not seen — budget allowing.
                    if !self.workers[w].outstanding.is_empty() {
                        return;
                    }
                    let Some(i) = (0..descs.len())
                        .filter(|&i| {
                            results[i].is_none()
                                && !dispatched[i].contains(&w)
                                && retries[i] < JOB_RETRIES
                        })
                        .min_by_key(|&i| dispatched[i].len())
                    else {
                        return;
                    };
                    retries[i] += 1; // the duplicate consumes retry budget
                    i
                }
            };
            // Prefer a worker that has not seen this job (a retried job
            // lands on a different process when one exists); fall back to
            // the least-loaded — on a one-worker pool the retry must
            // still go somewhere.
            let w = if dispatched[i].contains(&w) {
                self.workers
                    .iter()
                    .enumerate()
                    .filter(|(wi, wk)| {
                        wk.alive
                            && wk.outstanding.len() < PIPELINE
                            && !dispatched[i].contains(wi)
                    })
                    .min_by_key(|(_, wk)| wk.outstanding.len())
                    .map_or(w, |(wi, _)| wi)
            } else {
                w
            };
            let line = encode_job(base + i as u64, &descs[i]);
            let ok = match self.workers[w].stdin.as_mut() {
                Some(stdin) => writeln!(stdin, "{line}")
                    .and_then(|()| stdin.flush())
                    .is_ok(),
                None => false,
            };
            if ok {
                self.workers[w].outstanding.insert(i, Instant::now());
                dispatched[i].push(w);
            } else {
                // Broken pipe: handle the death here in full (the reader
                // thread's Dead event carries the replaced generation and
                // is ignored) so its outstanding jobs requeue exactly once.
                queue.push_front(i);
                self.kill_worker(w, "stdin write failed");
                Self::requeue(
                    &mut self.workers[w], results, queue, deaths, descs,
                );
                self.try_respawn(w);
            }
        }
    }

    fn kill_worker(&mut self, worker: usize, reason: &str) {
        let w = &mut self.workers[worker];
        w.alive = false;
        w.stdin = None;
        let _ = w.child.kill();
        let _ = w.child.wait();
        eprintln!("shard worker {worker} lost: {reason}");
    }

    /// Put a dead worker's unfinished jobs back on the queue, attributing
    /// the death to each; a job implicated in [`POISON_DEATHS`] deaths is
    /// propagated as a panic.
    fn requeue(
        worker: &mut Worker,
        results: &Slots,
        queue: &mut VecDeque<usize>,
        deaths: &mut [u32],
        descs: &[JobDesc],
    ) {
        for (i, _dispatched_at) in std::mem::take(&mut worker.outstanding) {
            if results[i].is_some() {
                continue;
            }
            deaths[i] += 1;
            if deaths[i] >= POISON_DEATHS {
                panic!(
                    "shard job {i} ({} on {}) killed {} workers — poison job \
                     propagated (in-process contract: a panicking job \
                     panics the batch)",
                    descs[i].model, descs[i].variant, deaths[i]
                );
            }
            if !queue.contains(&i) {
                queue.push_front(i);
            }
        }
    }
}

impl Drop for ShardPool {
    fn drop(&mut self) {
        for w in &mut self.workers {
            w.stdin = None; // EOF → graceful worker exit
        }
        for w in &mut self.workers {
            // Reap; workers exit on stdin EOF, kill covers wedged ones.
            let _ = w.child.kill();
            let _ = w.child.wait();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn hex_roundtrip() {
        let bytes: Vec<u8> = (0..=255).collect();
        assert_eq!(from_hex(&to_hex(&bytes)).unwrap(), bytes);
        assert_eq!(to_hex(&[0x00, 0xff, 0x7f]), "00ff7f");
        assert!(from_hex("abc").is_err());
        assert!(from_hex("zz").is_err());
    }

    #[test]
    fn job_line_roundtrip() {
        let d = JobDesc {
            model: "synth:tiny:3".into(),
            variant: "v4".into(),
            input: vec![0, 127, 128, 255],
            max_instrs: 1 << 36,
            program_fp: u64::MAX,
            base_dm_fp: 1,
        };
        let line = encode_job(42, &d);
        assert!(!line.contains('\n'));
        match parse_line(&line).unwrap() {
            Msg::Job { seq, desc } => {
                assert_eq!(seq, 42);
                assert_eq!(desc, d);
            }
            other => panic!("wrong message: {other:?}"),
        }
    }

    #[test]
    fn result_line_roundtrip() {
        let ok = Ok(JobOutput {
            output: vec![i32::MIN, -1, 0, i32::MAX],
            stats: RunStats { instrs: 123, cycles: 456 },
        });
        match parse_line(&encode_result(7, &ok)).unwrap() {
            Msg::Done { seq, result } => {
                assert_eq!(seq, 7);
                assert_eq!(result, ok);
            }
            other => panic!("wrong message: {other:?}"),
        }
        let err: Result<JobOutput, String> = Err("memory fault \"x\"".into());
        match parse_line(&encode_result(8, &err)).unwrap() {
            Msg::Done { seq, result } => {
                assert_eq!(seq, 8);
                assert_eq!(result, err);
            }
            other => panic!("wrong message: {other:?}"),
        }
    }

    #[test]
    fn ready_line_roundtrip() {
        assert_eq!(parse_line(&encode_ready()).unwrap(), Msg::Ready);
        assert!(parse_line("{\"type\":\"nope\"}").is_err());
        assert!(parse_line("not json").is_err());
    }

    #[test]
    fn capped_read_accepts_normal_lines() {
        let data: &[u8] = b"hello\nworld";
        let mut r = BufReader::new(data);
        assert_eq!(
            read_line_capped(&mut r, 64).unwrap().as_deref(),
            Some("hello")
        );
        // last line may arrive unterminated (peer died mid-write)
        assert_eq!(
            read_line_capped(&mut r, 64).unwrap().as_deref(),
            Some("world")
        );
        assert_eq!(read_line_capped(&mut r, 64).unwrap(), None);
    }

    #[test]
    fn capped_read_rejects_oversized_and_garbage() {
        let mut data = vec![b'a'; 100];
        data.push(b'\n');
        data.extend_from_slice(b"ok\n");
        let mut r = BufReader::new(&data[..]);
        let err = read_line_capped(&mut r, 10).unwrap_err();
        assert!(err.to_string().contains("oversized frame"), "{err}");
        // the violation classifies as fatal, never retried
        assert_eq!(
            RemoteKind::classify(&err.to_string()),
            RemoteKind::Fatal
        );
        // the terminated oversized line was consumed; the stream resyncs
        assert_eq!(
            read_line_capped(&mut r, 10).unwrap().as_deref(),
            Some("ok")
        );
        // non-UTF-8 bytes are corruption, not a line
        let mut r = BufReader::new(&[0xff, 0xfe, b'\n'][..]);
        let err = read_line_capped(&mut r, 10).unwrap_err();
        assert!(err.to_string().contains("non-UTF-8"), "{err}");
    }
}
