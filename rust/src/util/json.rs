//! Minimal JSON parser/serializer (serde is unavailable offline).
//!
//! Covers everything the exporter emits: objects, arrays, strings with
//! escapes, integers/floats, booleans, null.  Integers up to 2^53 round-trip
//! exactly (stored as f64, same as the Python `json` module's model).

use std::collections::BTreeMap;
use std::fmt;

use anyhow::{anyhow, bail, Context, Result};

/// A JSON value.
#[derive(Clone, Debug, PartialEq)]
pub enum Value {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Value>),
    Obj(BTreeMap<String, Value>),
}

impl Value {
    pub fn as_bool(&self) -> Result<bool> {
        match self {
            Value::Bool(b) => Ok(*b),
            _ => bail!("expected bool, got {self:?}"),
        }
    }

    pub fn as_f64(&self) -> Result<f64> {
        match self {
            Value::Num(n) => Ok(*n),
            _ => bail!("expected number, got {self:?}"),
        }
    }

    pub fn as_i64(&self) -> Result<i64> {
        let n = self.as_f64()?;
        if n.fract() != 0.0 || n.abs() > 2f64.powi(53) {
            bail!("expected integer, got {n}");
        }
        Ok(n as i64)
    }

    pub fn as_usize(&self) -> Result<usize> {
        let v = self.as_i64()?;
        usize::try_from(v).map_err(|_| anyhow!("expected unsigned, got {v}"))
    }

    /// Unsigned integer up to 2^53 (the f64-exact range, same contract as
    /// [`Self::as_i64`]).  Wire fields that must cover the full u64 range
    /// (fingerprints) travel as hex strings instead.
    pub fn as_u64(&self) -> Result<u64> {
        let v = self.as_i64()?;
        u64::try_from(v).map_err(|_| anyhow!("expected unsigned, got {v}"))
    }

    pub fn as_str(&self) -> Result<&str> {
        match self {
            Value::Str(s) => Ok(s),
            _ => bail!("expected string, got {self:?}"),
        }
    }

    pub fn as_arr(&self) -> Result<&[Value]> {
        match self {
            Value::Arr(a) => Ok(a),
            _ => bail!("expected array, got {self:?}"),
        }
    }

    pub fn as_obj(&self) -> Result<&BTreeMap<String, Value>> {
        match self {
            Value::Obj(o) => Ok(o),
            _ => bail!("expected object, got {self:?}"),
        }
    }

    /// Object field access with a path-aware error.
    pub fn get(&self, key: &str) -> Result<&Value> {
        self.as_obj()?
            .get(key)
            .ok_or_else(|| anyhow!("missing key {key:?}"))
    }

    /// `get` that tolerates absence (returns None for missing or null).
    pub fn get_opt(&self, key: &str) -> Option<&Value> {
        match self {
            Value::Obj(o) => match o.get(key) {
                Some(Value::Null) | None => None,
                Some(v) => Some(v),
            },
            _ => None,
        }
    }

    pub fn usize_list(&self, key: &str) -> Result<Vec<usize>> {
        self.get(key)?
            .as_arr()?
            .iter()
            .map(|v| v.as_usize())
            .collect::<Result<Vec<_>>>()
            .with_context(|| format!("field {key:?}"))
    }

    pub fn i64_list(&self, key: &str) -> Result<Vec<i64>> {
        self.get(key)?
            .as_arr()?
            .iter()
            .map(|v| v.as_i64())
            .collect::<Result<Vec<_>>>()
            .with_context(|| format!("field {key:?}"))
    }
}

impl From<i64> for Value {
    fn from(v: i64) -> Self {
        Value::Num(v as f64)
    }
}
impl From<u64> for Value {
    /// Exact only up to 2^53 (the shared f64 number model); the wire layer
    /// asserts this for its fields and moves wider values to hex strings.
    fn from(v: u64) -> Self {
        debug_assert!(v <= 1 << 53, "u64 {v} exceeds exact f64 range");
        Value::Num(v as f64)
    }
}
impl From<usize> for Value {
    fn from(v: usize) -> Self {
        Value::from(v as u64)
    }
}
impl From<f64> for Value {
    fn from(v: f64) -> Self {
        Value::Num(v)
    }
}
impl From<&str> for Value {
    fn from(v: &str) -> Self {
        Value::Str(v.to_string())
    }
}
impl From<String> for Value {
    fn from(v: String) -> Self {
        Value::Str(v)
    }
}
impl From<bool> for Value {
    fn from(v: bool) -> Self {
        Value::Bool(v)
    }
}
impl<T: Into<Value>> From<Vec<T>> for Value {
    fn from(v: Vec<T>) -> Self {
        Value::Arr(v.into_iter().map(Into::into).collect())
    }
}

/// Builder sugar for objects.
#[derive(Default)]
pub struct ObjBuilder(BTreeMap<String, Value>);

impl ObjBuilder {
    pub fn new() -> Self {
        Self::default()
    }
    pub fn set(mut self, k: &str, v: impl Into<Value>) -> Self {
        self.0.insert(k.to_string(), v.into());
        self
    }
    pub fn build(self) -> Value {
        Value::Obj(self.0)
    }
}

// ---------------------------------------------------------------------------
// Parser
// ---------------------------------------------------------------------------

struct Parser<'a> {
    s: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> anyhow::Error {
        let line = self.s[..self.pos].iter().filter(|&&c| c == b'\n').count() + 1;
        anyhow!("json parse error at byte {} (line {line}): {msg}", self.pos)
    }

    fn peek(&self) -> Option<u8> {
        self.s.get(self.pos).copied()
    }

    fn bump(&mut self) -> Option<u8> {
        let c = self.peek()?;
        self.pos += 1;
        Some(c)
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, c: u8) -> Result<()> {
        if self.bump() == Some(c) {
            Ok(())
        } else {
            Err(self.err(&format!("expected {:?}", c as char)))
        }
    }

    fn parse_value(&mut self) -> Result<Value> {
        self.skip_ws();
        match self.peek().ok_or_else(|| self.err("unexpected eof"))? {
            b'{' => self.parse_obj(),
            b'[' => self.parse_arr(),
            b'"' => Ok(Value::Str(self.parse_string()?)),
            b't' => self.parse_lit("true", Value::Bool(true)),
            b'f' => self.parse_lit("false", Value::Bool(false)),
            b'n' => self.parse_lit("null", Value::Null),
            b'-' | b'0'..=b'9' => self.parse_num(),
            c => Err(self.err(&format!("unexpected byte {:?}", c as char))),
        }
    }

    fn parse_lit(&mut self, lit: &str, v: Value) -> Result<Value> {
        if self.s[self.pos..].starts_with(lit.as_bytes()) {
            self.pos += lit.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected {lit}")))
        }
    }

    fn parse_num(&mut self) -> Result<Value> {
        let start = self.pos;
        while matches!(
            self.peek(),
            Some(b'-' | b'+' | b'.' | b'e' | b'E' | b'0'..=b'9')
        ) {
            self.pos += 1;
        }
        let txt = std::str::from_utf8(&self.s[start..self.pos]).unwrap();
        txt.parse::<f64>()
            .map(Value::Num)
            .map_err(|e| self.err(&format!("bad number {txt:?}: {e}")))
    }

    fn parse_string(&mut self) -> Result<String> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.bump().ok_or_else(|| self.err("eof in string"))? {
                b'"' => return Ok(out),
                b'\\' => match self.bump().ok_or_else(|| self.err("eof in escape"))? {
                    b'"' => out.push('"'),
                    b'\\' => out.push('\\'),
                    b'/' => out.push('/'),
                    b'b' => out.push('\u{8}'),
                    b'f' => out.push('\u{c}'),
                    b'n' => out.push('\n'),
                    b'r' => out.push('\r'),
                    b't' => out.push('\t'),
                    b'u' => {
                        if self.pos + 4 > self.s.len() {
                            return Err(self.err("eof in \\u escape"));
                        }
                        let hex =
                            std::str::from_utf8(&self.s[self.pos..self.pos + 4])
                                .map_err(|_| self.err("bad \\u escape"))?;
                        let cp = u32::from_str_radix(hex, 16)
                            .map_err(|_| self.err("bad \\u escape"))?;
                        self.pos += 4;
                        // Surrogate pairs are not emitted by our exporter;
                        // map unpaired surrogates to replacement char.
                        out.push(char::from_u32(cp).unwrap_or('\u{fffd}'));
                    }
                    c => return Err(self.err(&format!("bad escape \\{}", c as char))),
                },
                c if c < 0x80 => out.push(c as char),
                c => {
                    // multi-byte UTF-8: copy continuation bytes verbatim
                    let len = match c {
                        0xC0..=0xDF => 2,
                        0xE0..=0xEF => 3,
                        0xF0..=0xF7 => 4,
                        _ => return Err(self.err("bad utf8")),
                    };
                    let start = self.pos - 1;
                    self.pos += len - 1;
                    if self.pos > self.s.len() {
                        return Err(self.err("eof in utf8"));
                    }
                    let chunk = std::str::from_utf8(&self.s[start..self.pos])
                        .map_err(|_| self.err("bad utf8"))?;
                    out.push_str(chunk);
                }
            }
        }
    }

    fn parse_arr(&mut self) -> Result<Value> {
        self.expect(b'[')?;
        let mut out = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Value::Arr(out));
        }
        loop {
            out.push(self.parse_value()?);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b']') => return Ok(Value::Arr(out)),
                _ => return Err(self.err("expected , or ]")),
            }
        }
    }

    fn parse_obj(&mut self) -> Result<Value> {
        self.expect(b'{')?;
        let mut out = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Value::Obj(out));
        }
        loop {
            self.skip_ws();
            let key = self.parse_string()?;
            self.skip_ws();
            self.expect(b':')?;
            let val = self.parse_value()?;
            out.insert(key, val);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b'}') => return Ok(Value::Obj(out)),
                _ => return Err(self.err("expected , or }")),
            }
        }
    }
}

/// Parse a JSON document.
pub fn parse(s: &str) -> Result<Value> {
    let mut p = Parser { s: s.as_bytes(), pos: 0 };
    let v = p.parse_value()?;
    p.skip_ws();
    if p.pos != p.s.len() {
        return Err(p.err("trailing garbage"));
    }
    Ok(v)
}

/// Parse a JSON file.
pub fn parse_file(path: &std::path::Path) -> Result<Value> {
    let text = std::fs::read_to_string(path)
        .with_context(|| format!("reading {}", path.display()))?;
    parse(&text).with_context(|| format!("parsing {}", path.display()))
}

// ---------------------------------------------------------------------------
// Serializer
// ---------------------------------------------------------------------------

fn escape(s: &str, out: &mut String) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                out.push_str(&format!("\\u{:04x}", c as u32))
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

fn write_val(v: &Value, indent: usize, out: &mut String) {
    let pad = |n: usize, out: &mut String| {
        out.push('\n');
        for _ in 0..n {
            out.push(' ');
        }
    };
    match v {
        Value::Null => out.push_str("null"),
        Value::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
        Value::Num(n) => {
            if n.fract() == 0.0 && n.abs() < 2f64.powi(53) {
                out.push_str(&format!("{}", *n as i64));
            } else {
                out.push_str(&format!("{n}"));
            }
        }
        Value::Str(s) => escape(s, out),
        Value::Arr(a) => {
            if a.is_empty() {
                out.push_str("[]");
                return;
            }
            out.push('[');
            for (i, item) in a.iter().enumerate() {
                if i > 0 {
                    out.push(',');
                }
                pad(indent + 1, out);
                write_val(item, indent + 1, out);
            }
            pad(indent, out);
            out.push(']');
        }
        Value::Obj(o) => {
            if o.is_empty() {
                out.push_str("{}");
                return;
            }
            out.push('{');
            for (i, (k, val)) in o.iter().enumerate() {
                if i > 0 {
                    out.push(',');
                }
                pad(indent + 1, out);
                escape(k, out);
                out.push_str(": ");
                write_val(val, indent + 1, out);
            }
            pad(indent, out);
            out.push('}');
        }
    }
}

/// Pretty-print a value (1-space indent, like the exporter's `indent=1`).
pub fn to_string(v: &Value) -> String {
    let mut out = String::new();
    write_val(v, 0, &mut out);
    out
}

fn write_compact(v: &Value, out: &mut String) {
    match v {
        Value::Null | Value::Bool(_) | Value::Num(_) | Value::Str(_) => {
            write_val(v, 0, out)
        }
        Value::Arr(a) => {
            out.push('[');
            for (i, item) in a.iter().enumerate() {
                if i > 0 {
                    out.push(',');
                }
                write_compact(item, out);
            }
            out.push(']');
        }
        Value::Obj(o) => {
            out.push('{');
            for (i, (k, val)) in o.iter().enumerate() {
                if i > 0 {
                    out.push(',');
                }
                escape(k, out);
                out.push(':');
                write_compact(val, out);
            }
            out.push('}');
        }
    }
}

/// Serialize to a single line with no whitespace — the form the shard/serve
/// wire protocols need, where one JSON document per `\n`-terminated line is
/// the framing ([`crate::sim::shard`]).
pub fn to_compact_string(v: &Value) -> String {
    let mut out = String::new();
    write_compact(v, &mut out);
    out
}

impl fmt::Display for Value {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&to_string(self))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_scalars() {
        assert_eq!(parse("42").unwrap().as_i64().unwrap(), 42);
        assert_eq!(parse("-7").unwrap().as_i64().unwrap(), -7);
        assert_eq!(parse("2.5").unwrap().as_f64().unwrap(), 2.5);
        assert_eq!(parse("true").unwrap(), Value::Bool(true));
        assert_eq!(parse("null").unwrap(), Value::Null);
        assert_eq!(parse(r#""hi\nthere""#).unwrap().as_str().unwrap(), "hi\nthere");
    }

    #[test]
    fn parse_nested() {
        let v = parse(r#"{"a": [1, 2, {"b": "c"}], "d": null}"#).unwrap();
        assert_eq!(v.get("a").unwrap().as_arr().unwrap().len(), 3);
        assert!(v.get_opt("d").is_none());
        assert!(v.get_opt("missing").is_none());
    }

    #[test]
    fn roundtrip() {
        let src = r#"{"layers": [{"op": "conv2d", "shift": 7, "relu": true,
                      "shape": [3, 32, 32]}], "name": "m", "pi": 3.5}"#;
        let v = parse(src).unwrap();
        let txt = to_string(&v);
        assert_eq!(parse(&txt).unwrap(), v);
    }

    #[test]
    fn unicode_and_escapes() {
        let v = parse(r#""café ✓""#).unwrap();
        assert_eq!(v.as_str().unwrap(), "café ✓");
        let back = to_string(&v);
        assert_eq!(parse(&back).unwrap(), v);
    }

    #[test]
    fn errors_are_positioned() {
        let e = parse("{\"a\": \n  [1, 2,]}").unwrap_err().to_string();
        assert!(e.contains("line 2"), "{e}");
        assert!(parse("{").is_err());
        assert!(parse("[1] trailing").is_err());
        assert!(parse("0x10").is_err());
    }

    #[test]
    fn int_fidelity() {
        // 2^52 + 1 must round-trip
        let n = (1i64 << 52) + 1;
        let v = parse(&n.to_string()).unwrap();
        assert_eq!(v.as_i64().unwrap(), n);
        assert!(parse("1e60").unwrap().as_i64().is_err());
    }

    #[test]
    fn compact_is_one_line_and_roundtrips() {
        let src = r#"{"a": [1, {"b": "x\ny"}, null], "c": true, "d": 2.5}"#;
        let v = parse(src).unwrap();
        let line = to_compact_string(&v);
        assert!(!line.contains('\n'), "{line}");
        assert!(!line.contains(": "), "no pretty separators: {line}");
        assert_eq!(parse(&line).unwrap(), v);
        assert_eq!(line, r#"{"a":[1,{"b":"x\ny"},null],"c":true,"d":2.5}"#);
    }

    #[test]
    fn u64_fields() {
        let v = ObjBuilder::new().set("n", 42u64).set("z", 0usize).build();
        assert_eq!(v.get("n").unwrap().as_u64().unwrap(), 42);
        assert_eq!(v.get("z").unwrap().as_u64().unwrap(), 0);
        assert!(parse("-1").unwrap().as_u64().is_err());
    }

    #[test]
    fn builder() {
        let v = ObjBuilder::new()
            .set("x", 3i64)
            .set("name", "m")
            .set("ok", true)
            .set("xs", vec![1i64, 2])
            .build();
        assert_eq!(v.get("x").unwrap().as_i64().unwrap(), 3);
        assert_eq!(v.i64_list("xs").unwrap(), vec![1, 2]);
    }
}
