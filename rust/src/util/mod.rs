//! Small self-contained utilities (the offline substitutes for serde,
//! proptest and prettytable).

pub mod json;
pub mod proptest;
pub mod rng;
pub mod tables;

/// FNV-1a offset basis — the seed for [`fnv1a_extend`] chains.
pub const FNV_OFFSET: u64 = 0xcbf2_9ce4_8422_2325;

/// Fold `bytes` into a running FNV-1a hash.  The single definition every
/// fingerprint in the repo shares (`Program::fingerprint`, the compile
/// cache key, the shard wire's base-DM check): the shard layer compares
/// hashes computed in different processes, so divergent copies of the
/// algorithm would surface as spurious fingerprint-mismatch errors.
pub fn fnv1a_extend(mut h: u64, bytes: &[u8]) -> u64 {
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x100_0000_01b3);
    }
    h
}

/// FNV-1a over one byte slice.
pub fn fnv1a(bytes: &[u8]) -> u64 {
    fnv1a_extend(FNV_OFFSET, bytes)
}

#[cfg(test)]
mod fnv_tests {
    use super::*;

    #[test]
    fn fnv1a_reference_vectors() {
        // Published FNV-1a 64-bit test vectors.
        assert_eq!(fnv1a(b""), 0xcbf2_9ce4_8422_2325);
        assert_eq!(fnv1a(b"a"), 0xaf63_dc4c_8601_ec8c);
        assert_eq!(fnv1a(b"foobar"), 0x85944171f73967e8);
        // extend() chains identically to one flat pass
        assert_eq!(fnv1a_extend(fnv1a(b"foo"), b"bar"), fnv1a(b"foobar"));
    }
}
