//! Small self-contained utilities (the offline substitutes for serde,
//! proptest and prettytable).

pub mod json;
pub mod proptest;
pub mod rng;
pub mod tables;
