//! Hand-rolled property-test harness (proptest is unavailable offline).
//!
//! `check(name, cases, |rng| ...)` runs the closure `cases` times with
//! independent deterministic seeds and panics with the failing seed on the
//! first error, so a failure reproduces with `check_seed(name, seed, f)`.
//! No shrinking — generators here are small enough that the failing case is
//! directly debuggable from the seed.

use super::rng::Rng;

/// Run `f` against `cases` seeded RNGs; panic with the seed on failure.
pub fn check<F>(name: &str, cases: u64, mut f: F)
where
    F: FnMut(&mut Rng) -> Result<(), String>,
{
    // Base seed is fixed for reproducibility; MARVEL_PROP_SEED overrides.
    let base = std::env::var("MARVEL_PROP_SEED")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(0xC0FF_EE00u64);
    for case in 0..cases {
        let seed = base.wrapping_add(case.wrapping_mul(0x9E37_79B9));
        let mut rng = Rng::new(seed);
        if let Err(msg) = f(&mut rng) {
            panic!(
                "property {name:?} failed at case {case} (seed {seed:#x}):\n{msg}\n\
                 reproduce with MARVEL_PROP_SEED={base} or check_seed({seed:#x})"
            );
        }
    }
}

/// Re-run a single failing case by seed.
pub fn check_seed<F>(name: &str, seed: u64, mut f: F)
where
    F: FnMut(&mut Rng) -> Result<(), String>,
{
    let mut rng = Rng::new(seed);
    if let Err(msg) = f(&mut rng) {
        panic!("property {name:?} failed (seed {seed:#x}):\n{msg}");
    }
}

/// Assert-style helper returning Err for the harness.
#[macro_export]
macro_rules! prop_assert {
    ($cond:expr, $($fmt:tt)+) => {
        if !$cond {
            return Err(format!($($fmt)+));
        }
    };
}

/// Equality helper with value dump.
#[macro_export]
macro_rules! prop_assert_eq {
    ($a:expr, $b:expr, $($fmt:tt)+) => {{
        let (a, b) = (&$a, &$b);
        if a != b {
            return Err(format!(
                "{}\n  left:  {:?}\n  right: {:?}", format!($($fmt)+), a, b));
        }
    }};
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_runs_all_cases() {
        let mut n = 0;
        check("trivial", 50, |rng| {
            n += 1;
            let v = rng.int_in(0, 10);
            if (0..=10).contains(&v) {
                Ok(())
            } else {
                Err("out of range".into())
            }
        });
        assert_eq!(n, 50);
    }

    #[test]
    #[should_panic(expected = "failed at case")]
    fn failing_property_panics_with_seed() {
        check("always-fails", 3, |_| Err("nope".into()));
    }
}
