//! Deterministic PRNG (splitmix64): seeds the property-test harness and the
//! synthetic spec builders.  No external `rand` dependency is available
//! offline, and determinism across runs matters more than statistical
//! quality here.

/// Splitmix64 PRNG. Tiny state, passes BigCrush for our purposes.
#[derive(Clone, Debug)]
pub struct Rng {
    state: u64,
}

impl Rng {
    pub fn new(seed: u64) -> Self {
        Rng { state: seed }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E37_79B9_7F4A_7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
        z ^ (z >> 31)
    }

    #[inline]
    pub fn next_u32(&mut self) -> u32 {
        (self.next_u64() >> 32) as u32
    }

    /// Uniform in [lo, hi) — panics if lo >= hi.
    pub fn range_i64(&mut self, lo: i64, hi: i64) -> i64 {
        assert!(lo < hi, "empty range [{lo}, {hi})");
        let span = (hi - lo) as u64;
        lo + (self.next_u64() % span) as i64
    }

    pub fn range_usize(&mut self, lo: usize, hi: usize) -> usize {
        self.range_i64(lo as i64, hi as i64) as usize
    }

    /// Uniform i32 in [lo, hi] inclusive.
    pub fn int_in(&mut self, lo: i32, hi: i32) -> i32 {
        self.range_i64(lo as i64, hi as i64 + 1) as i32
    }

    pub fn bool(&mut self) -> bool {
        self.next_u64() & 1 == 1
    }

    /// Pick a random element of a slice.
    pub fn choice<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.range_usize(0, xs.len())]
    }

    /// int8-range value.
    pub fn int8(&mut self) -> i32 {
        self.int_in(-128, 127)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn range_bounds() {
        let mut r = Rng::new(7);
        for _ in 0..1000 {
            let v = r.range_i64(-5, 5);
            assert!((-5..5).contains(&v));
            let u = r.int_in(-128, 127);
            assert!((-128..=127).contains(&u));
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        let va: Vec<u64> = (0..8).map(|_| a.next_u64()).collect();
        let vb: Vec<u64> = (0..8).map(|_| b.next_u64()).collect();
        assert_ne!(va, vb);
    }

    #[test]
    #[should_panic(expected = "empty range")]
    fn empty_range_panics() {
        Rng::new(0).range_i64(3, 3);
    }
}
