//! ASCII table rendering for the experiment reports (`marvel report ...`),
//! mirroring the row/column structure of the paper's tables.

/// A simple left/right-aligned ASCII table.
pub struct Table {
    headers: Vec<String>,
    rows: Vec<Vec<String>>,
    title: Option<String>,
}

impl Table {
    pub fn new(headers: &[&str]) -> Self {
        Table {
            headers: headers.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
            title: None,
        }
    }

    pub fn with_title(mut self, t: &str) -> Self {
        self.title = Some(t.to_string());
        self
    }

    pub fn row(&mut self, cells: Vec<String>) -> &mut Self {
        assert_eq!(
            cells.len(),
            self.headers.len(),
            "row width mismatch: {cells:?}"
        );
        self.rows.push(cells);
        self
    }

    pub fn render(&self) -> String {

        let mut widths: Vec<usize> =
            self.headers.iter().map(|h| h.chars().count()).collect();
        for r in &self.rows {
            for (i, c) in r.iter().enumerate() {
                widths[i] = widths[i].max(c.chars().count());
            }
        }
        let sep = |out: &mut String| {
            out.push('+');
            for w in &widths {
                out.push_str(&"-".repeat(w + 2));
                out.push('+');
            }
            out.push('\n');
        };
        let line = |cells: &[String], out: &mut String| {
            out.push('|');
            for (i, c) in cells.iter().enumerate() {
                // first column left-aligned, rest right-aligned (numbers)
                let w = widths[i];
                let pad = w - c.chars().count();
                if i == 0 {
                    out.push_str(&format!(" {}{} |", c, " ".repeat(pad)));
                } else {
                    out.push_str(&format!(" {}{} |", " ".repeat(pad), c));
                }
            }
            out.push('\n');
        };
        let mut out = String::new();
        if let Some(t) = &self.title {
            out.push_str(t);
            out.push('\n');
        }
        sep(&mut out);
        line(&self.headers, &mut out);
        sep(&mut out);
        for r in &self.rows {
            line(r, &mut out);
        }
        sep(&mut out);
        out
    }
}

/// Format a count with thousands separators (`1,234,567`).
pub fn fmt_count(n: u64) -> String {
    let s = n.to_string();
    let mut out = String::new();
    for (i, c) in s.chars().enumerate() {
        if i > 0 && (s.len() - i) % 3 == 0 {
            out.push(',');
        }
        out.push(c);
    }
    out
}

/// Human-readable count (1.23M, 4.56B).
pub fn fmt_si(n: u64) -> String {
    let f = n as f64;
    if f >= 1e9 {
        format!("{:.2}B", f / 1e9)
    } else if f >= 1e6 {
        format!("{:.2}M", f / 1e6)
    } else if f >= 1e3 {
        format!("{:.1}K", f / 1e3)
    } else {
        format!("{n}")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_aligned() {
        let mut t = Table::new(&["model", "cycles"]).with_title("T");
        t.row(vec!["lenet5".into(), "123".into()]);
        t.row(vec!["vgg16".into(), "4567890".into()]);
        let s = t.render();
        assert!(s.contains("| model  |"), "{s}");
        assert!(s.contains("| vgg16  | 4567890 |"), "{s}");
        // all lines same width
        let w: Vec<usize> = s.lines().skip(1).map(|l| l.len()).collect();
        assert!(w.windows(2).all(|p| p[0] == p[1]), "{s}");
    }

    #[test]
    #[should_panic(expected = "row width mismatch")]
    fn row_width_checked() {
        Table::new(&["a", "b"]).row(vec!["x".into()]);
    }

    #[test]
    fn count_formatting() {
        assert_eq!(fmt_count(1234567), "1,234,567");
        assert_eq!(fmt_count(17), "17");
        assert_eq!(fmt_si(1_890_000_000), "1.89B");
        assert_eq!(fmt_si(23_600_000), "23.60M");
        assert_eq!(fmt_si(950), "950");
    }
}
