//! Fault-injection (chaos) integration tests (DESIGN.md §16).
//!
//! The contract under test: a deterministic [`FaultPlan`] within the
//! recovery budgets is **invisible** — results stay bit-identical to a
//! clean in-process run — and a plan *past* budget surfaces as a fatal,
//! classified error at exactly the faulted job's index.  Three seams:
//!
//! 1. **Exec site, determinism** — a seeded plan replayed through
//!    [`ChaosExec`] over [`LocalExec`] produces the same bytes twice, and
//!    the same bytes as a chaos-free run (injected transients heal inside
//!    the wrapper's retry budget; delays and duplicates never touch
//!    results).
//! 2. **Worker site, real recovery** — worker kills, corrupted wire
//!    lines, transients, delays and duplicated result lines injected
//!    inside real `marvel shard-worker` processes (plan delivered via
//!    `MARVEL_CHAOS`) exercise the coordinator's death requeue + respawn
//!    and retry machinery; a 2-process pool's results must match the
//!    in-process engine bit for bit.
//! 3. **Exec site, budget exhaustion** — a fault repeating past
//!    [`CHAOS_EXEC_RETRIES`] yields a fatal `retry budget exhausted`
//!    [`SimError::Remote`] at the faulted index; every other job is
//!    untouched.
//!
//! Like tests/shard.rs, the process-spawning test uses the real `marvel`
//! binary via `CARGO_BIN_EXE_marvel` and synthetic models, so no
//! artifacts directory is needed.

use std::path::{Path, PathBuf};

use marvel::sim::chaos::{CHAOS_EXEC_RETRIES, MARVEL_CHAOS_ENV};
use marvel::sim::exec::{Executor, JobSpec, LocalExec};
use marvel::sim::shard::{self, desc_for, run_descs_local, JobDesc,
                         ShardPool, WorkerCmd};
use marvel::sim::{ChaosExec, FaultPlan, JobOutput, RemoteKind, SimError,
                  V0, V4};
use marvel::util::rng::Rng;

/// The real worker binary with a chaos plan delivered the way the CLI
/// delivers it: through the `MARVEL_CHAOS` environment (an explicit
/// `envs` entry, so the coordinator's own environment stays untouched).
fn chaos_worker_cmd(plan: &str) -> WorkerCmd {
    WorkerCmd {
        program: PathBuf::from(env!("CARGO_BIN_EXE_marvel")),
        envs: vec![(MARVEL_CHAOS_ENV.to_string(), plan.to_string())],
        args: vec![
            "shard-worker".to_string(),
            "--artifacts".to_string(),
            "artifacts".to_string(),
        ],
    }
}

/// Deterministic job descriptions for `models` × {v0, v4} × `n_inputs`,
/// hydrated through the same path the worker uses (tests/shard.rs idiom).
fn descs_for(models: &[&str], n_inputs: usize) -> Vec<JobDesc> {
    let artifacts = Path::new("artifacts");
    let mut hyd = shard::Hydrator::new(artifacts);
    let mut descs = Vec::new();
    for (mi, model) in models.iter().enumerate() {
        let spec = marvel::models::resolve(artifacts, model).unwrap();
        let mut rng = Rng::new(2000 + mi as u64);
        for v in [V0, V4] {
            let (c, _) = hyd.hydrate(model, v.name).unwrap();
            for _ in 0..n_inputs {
                let input = marvel::models::synth::Builder::random_input(
                    &spec, &mut rng,
                );
                let packed = marvel::compiler::pack_input(&input).unwrap();
                descs.push(desc_for(model, &c, &packed, 1 << 33));
            }
        }
    }
    descs
}

/// 1. Same seed ⇒ same schedule ⇒ same bytes: a seeded exec-site plan is
/// deterministic across replays and invisible next to a clean run.
#[test]
fn seeded_exec_chaos_is_deterministic_and_invisible() {
    let artifacts = Path::new("artifacts");
    // 2 models × 2 variants × 8 inputs = 32 jobs — one per possible
    // generated trigger index, so every fault in the plan can fire.
    let descs = descs_for(&["synth:tiny:3", "synth:tiny:4"], 8);
    assert_eq!(descs.len(), 32);
    let clean = run_descs_local(artifacts, &descs, 0);

    let plan = FaultPlan::parse("seed:42:12").unwrap();
    assert_eq!(plan.faults.len(), 12);
    assert!(
        plan.faults.iter().all(|f| f.at < 32),
        "generated triggers must land inside this batch"
    );
    let run_chaos = || -> Vec<Result<JobOutput, SimError>> {
        let mut exec = ChaosExec::new(
            Box::new(LocalExec::new(artifacts, 2)),
            &plan,
        );
        assert_eq!(exec.describe(), "chaos(local:2)");
        for d in &descs {
            exec.submit(JobSpec::named(d.clone()));
        }
        exec.run()
    };
    let first = run_chaos();
    let second = run_chaos();
    assert_eq!(first.len(), clean.len());
    for (i, ((a, b), l)) in first.iter().zip(&second).zip(&clean).enumerate()
    {
        let a = a.as_ref().expect("in-budget chaos must heal");
        let b = b.as_ref().expect("in-budget chaos must heal on replay");
        let l = l.as_ref().unwrap();
        assert_eq!(a, l, "job {i}: chaos run diverged from clean run");
        assert_eq!(b, l, "job {i}: chaos replay diverged from clean run");
    }
}

/// 2. Worker-site faults within the budgets — an injected mid-sweep kill,
/// a corrupted result line, and a kill alongside transient/delay/dup
/// riders — leave a 2-process sharded sweep bit-identical to the
/// in-process engine.  Completion + `respawns_used` pin down that the
/// real death machinery (requeue + respawn) ran, not a lucky path.
#[test]
fn worker_faults_within_budget_shard_matches_local() {
    let artifacts = Path::new("artifacts");
    let descs = descs_for(&["synth:tiny:3", "synth:lenet:5"], 4);
    let clean = run_descs_local(artifacts, &descs, 0);
    for plan in [
        "worker:kill@3",
        "worker:corrupt@5",
        "worker:kill@2,worker:transient@6,worker:delay@4:5,worker:dup@7",
    ] {
        let mut pool = ShardPool::spawn(&chaos_worker_cmd(plan), 2).unwrap();
        let r = pool.run(&descs);
        assert!(
            pool.respawns_used() >= 1,
            "{plan}: the injected death must have cost a respawn"
        );
        assert_eq!(r.len(), clean.len());
        for (i, (got, want)) in r.iter().zip(&clean).enumerate() {
            assert_eq!(
                got.as_ref().unwrap(),
                want.as_ref().unwrap(),
                "{plan}: job {i} diverged after injected faults"
            );
        }
    }
}

/// 3. A fault that keeps firing past [`CHAOS_EXEC_RETRIES`] surfaces as a
/// *fatal* classified `retry budget exhausted` error at exactly the
/// faulted index; every other job runs clean.
#[test]
fn exec_budget_exhaustion_is_fatal_at_the_faulted_index() {
    let artifacts = Path::new("artifacts");
    let descs = descs_for(&["synth:tiny:3"], 3); // 6 jobs
    let clean = run_descs_local(artifacts, &descs, 0);
    // Enough repeats to outlast the wrapper's retry budget.
    let plan = FaultPlan::parse(&format!(
        "transient@2x{}",
        CHAOS_EXEC_RETRIES + 2
    ))
    .unwrap();
    let mut exec =
        ChaosExec::new(Box::new(LocalExec::new(artifacts, 2)), &plan);
    for d in &descs {
        exec.submit(JobSpec::named(d.clone()));
    }
    let r = exec.run();
    assert_eq!(r.len(), clean.len());
    match &r[2] {
        Err(SimError::Remote { msg, kind }) => {
            assert_eq!(
                *kind,
                RemoteKind::Fatal,
                "exhausted budget must not classify as retryable: {msg}"
            );
            assert!(msg.contains("retry budget exhausted"), "{msg}");
            assert!(msg.contains("at job 2"), "{msg}");
        }
        other => panic!("job 2 must fail fatally, got {other:?}"),
    }
    for (i, (got, want)) in r.iter().zip(&clean).enumerate() {
        if i == 2 {
            continue;
        }
        assert_eq!(
            got.as_ref().unwrap(),
            want.as_ref().unwrap(),
            "job {i} must be untouched by job 2's exhausted budget"
        );
    }
}
