//! Batch-engine contract tests (DESIGN.md §3):
//!
//! 1. **Equivalence** — a batch-engine job produces the same logits and the
//!    same `RunStats` as the single-threaded `Machine::run` path, for every
//!    variant on `lenet_shaped` and `residual_net`.
//! 2. **Determinism** — a variants × inputs batch is byte-identical across
//!    1, 2 and 8 worker threads (result order is submission order).
//! 3. **Sharing** — jobs hold the compiler's `Program` by `Arc`, never a
//!    copy.

use std::sync::Arc;

use marvel::compiler::{compile, execute_compiled, make_job, pack_input,
                       CompileCache};
use marvel::models::synth::{lenet_shaped, residual_net, tiny_conv_net,
                            Builder};
use marvel::sim::engine::{run_batch, run_job, run_job_on, run_job_pooled,
                          Job};
use marvel::sim::{Machine, NopHook, VARIANTS};
use marvel::util::rng::Rng;

#[test]
fn batch_engine_matches_single_threaded_sim() {
    for (spec, seed) in [(lenet_shaped(21), 31u64), (residual_net(9), 32u64)] {
        let mut rng = Rng::new(seed);
        let input = Builder::random_input(&spec, &mut rng);
        let packed = pack_input(&input).unwrap();
        for v in VARIANTS {
            let c = compile(&spec, v).unwrap();
            let (want_out, want_stats) =
                execute_compiled(&c, &spec, &input, 1 << 33, &mut NopHook)
                    .unwrap();
            let jobs = vec![make_job(&c, &spec, &packed, 1 << 33)];
            let got = run_batch(&jobs, 0).remove(0).unwrap();
            assert_eq!(got.output, want_out, "{} on {}", spec.name, v.name);
            assert_eq!(got.stats, want_stats, "{} on {}", spec.name, v.name);
        }
    }
}

#[test]
fn batch_results_identical_across_worker_counts() {
    let spec = lenet_shaped(33);
    let mut rng = Rng::new(77);
    let inputs: Vec<Vec<i32>> =
        (0..3).map(|_| Builder::random_input(&spec, &mut rng)).collect();

    let packed: Vec<Vec<u8>> =
        inputs.iter().map(|x| pack_input(x).unwrap()).collect();

    let cache = CompileCache::new();
    let compiled: Vec<_> = VARIANTS
        .iter()
        .map(|&v| cache.get_or_compile(&spec, v).unwrap())
        .collect();
    let mut jobs: Vec<Job<'_>> = Vec::new();
    for c in &compiled {
        for x in &packed {
            jobs.push(make_job(c, &spec, x, 1 << 33));
        }
    }

    let baseline: Vec<_> =
        run_batch(&jobs, 1).into_iter().map(|r| r.unwrap()).collect();
    assert_eq!(baseline.len(), VARIANTS.len() * inputs.len());
    for threads in [2, 8] {
        let got: Vec<_> = run_batch(&jobs, threads)
            .into_iter()
            .map(|r| r.unwrap())
            .collect();
        assert_eq!(got, baseline, "threads={threads} must be byte-identical");
    }

    // all variants agree on the logits for each input (batch order is
    // unit-major: run j belongs to variant j / n, input j % n)
    let n = inputs.len();
    for i in 0..n {
        for u in 1..VARIANTS.len() {
            assert_eq!(
                baseline[u * n + i].output,
                baseline[i].output,
                "variant {} input {i}",
                VARIANTS[u].name
            );
        }
    }
}

/// Pool-reuse contract (DESIGN.md §3): a machine recycled through the
/// pooled path — across different models, variants and DM sizes — produces
/// the same outputs *and* ends in the same architectural state as a fresh
/// machine.
#[test]
fn recycled_machine_is_indistinguishable_from_fresh() {
    let spec_a = tiny_conv_net(41);
    let spec_b = lenet_shaped(42);
    let ca = compile(&spec_a, marvel::sim::V0).unwrap();
    let cb = compile(&spec_b, marvel::sim::V4).unwrap();
    let mut rng = Rng::new(7);
    let input_a = Builder::random_input(&spec_a, &mut rng);
    let input_b = Builder::random_input(&spec_b, &mut rng);
    let packed_a = pack_input(&input_a).unwrap();
    let packed_b = pack_input(&input_b).unwrap();
    let job_a = make_job(&ca, &spec_a, &packed_a, 1 << 33);
    let job_b = make_job(&cb, &spec_b, &packed_b, 1 << 33);

    // run A then B through one pooled machine; B must match the
    // fresh-machine result exactly
    let fresh_out = run_job(&job_b).unwrap();
    let mut pool: Option<Machine> = None;
    run_job_pooled(&mut pool, &job_a).unwrap();
    let pooled_out = run_job_pooled(&mut pool, &job_b).unwrap();
    assert_eq!(pooled_out, fresh_out);

    // ... and the recycled machine's end state matches a fresh machine's
    let mut fresh = Machine::new(Arc::clone(&cb.program), 0);
    let fresh_again = run_job_on(&mut fresh, &job_b).unwrap();
    assert_eq!(fresh_again, fresh_out);
    let recycled = pool.as_ref().unwrap();
    assert_eq!(recycled.regs, fresh.regs);
    assert_eq!(recycled.pc, fresh.pc);
    assert_eq!(
        (recycled.zc, recycled.zs, recycled.ze),
        (fresh.zc, fresh.zs, fresh.ze)
    );
    assert_eq!(recycled.mem.len(), fresh.mem.len());
    assert_eq!(
        recycled.mem.read_block(0, recycled.mem.len()).unwrap(),
        fresh.mem.read_block(0, fresh.mem.len()).unwrap()
    );
    assert!(Arc::ptr_eq(recycled.program(), &cb.program));
}

#[test]
fn jobs_share_the_compiled_program() {
    let spec = lenet_shaped(5);
    let mut rng = Rng::new(9);
    let input = Builder::random_input(&spec, &mut rng);
    let c = compile(&spec, marvel::sim::V4).unwrap();
    let packed = pack_input(&input).unwrap();
    let a = make_job(&c, &spec, &packed, 1 << 33);
    let b = make_job(&c, &spec, &packed, 1 << 33);
    assert!(Arc::ptr_eq(&a.program, &c.program));
    assert!(Arc::ptr_eq(&a.program, &b.program));
    // the packed input is borrowed, not duplicated per job
    assert!(std::ptr::eq(a.input.1, b.input.1));
}
