//! Executor conformance suite (DESIGN.md §13): one shared harness, run
//! against every backend of the matrix, proving the contract the
//! `Executor` trait promises:
//!
//! 1. **Determinism + bit-identity** — the same batch run twice on the
//!    same executor, and once on every other backend, produces identical
//!    logits and `RunStats` (equal to the `run_descs_local` reference).
//! 2. **Submission order** — `results[i]` corresponds to the job whose
//!    `submit` returned `i`; per-job failures (watchdog, hydration) stay
//!    at their index.
//! 3. **DM-size interleaving** — the batch round-robins models with
//!    different data-memory footprints, so pooled machines rebind/reset
//!    across sizes without leaking bytes.
//! 4. **Poison-job panic propagation** — a job that panics a worker
//!    thread (local) or keeps killing worker processes (shard) panics the
//!    caller instead of returning a partial result.
//! 5. **Capabilities** — `Work::Raw` jobs run in-process but are refused,
//!    at their index, by a `cross_process` backend.
//!
//! Like `tests/shard.rs`, the process-spawning cases use the real
//! `marvel` binary (`CARGO_BIN_EXE_marvel`) and synthetic models, so no
//! artifacts directory is needed.  The cluster cells spawn real
//! `cluster-worker` daemons on ephemeral loopback ports, so the full TCP
//! transport — framing, handshake, re-dial recovery — is under the same
//! differential as the in-process backends.

use std::path::{Path, PathBuf};
use std::sync::Arc;

use marvel::compiler::pack_input;
use marvel::isa::{AluImmOp, Instr, LoadOp, StoreOp};
use marvel::sim::cluster::ClusterExec;
use marvel::sim::exec::{Executor, JobSpec, LocalExec, RawJob, ShardExec};
use marvel::sim::shard::{self, run_descs_local, JobDesc, ShardPool,
                         WorkerCmd};
use marvel::sim::{FaultPlan, Program, SimError, V0, V4};
use marvel::util::rng::Rng;

fn marvel_worker_cmd() -> WorkerCmd {
    WorkerCmd {
        program: PathBuf::from(env!("CARGO_BIN_EXE_marvel")),
        envs: Vec::new(),
        args: vec![
            "shard-worker".to_string(),
            "--artifacts".to_string(),
            "artifacts".to_string(),
        ],
    }
}

/// A `cluster:N` backend over real loopback daemons of the `marvel`
/// binary (the test harness's own `current_exe` has no `cluster-worker`
/// subcommand, so the binary is named explicitly).
fn cluster_exec(n: usize) -> ClusterExec {
    ClusterExec::spawn_loopback_cmd(
        Path::new(env!("CARGO_BIN_EXE_marvel")),
        Path::new("artifacts"),
        n,
        None,
    )
    .unwrap()
}

/// The backend matrix every conformance check runs against.
fn backends() -> Vec<Box<dyn Executor>> {
    vec![
        Box::new(LocalExec::new(Path::new("artifacts"), 1)),
        Box::new(LocalExec::new(Path::new("artifacts"), 4)),
        Box::new(ShardExec::from_pool(
            ShardPool::spawn(&marvel_worker_cmd(), 2).unwrap(),
            2,
        )),
        Box::new(cluster_exec(2)),
    ]
}

/// Deterministic job descriptions over a small synthetic zoo,
/// round-robin-interleaved across models so consecutive jobs have
/// different DM footprints (the pool rebind/reset stress of DESIGN.md §3).
fn zoo_descs(n_inputs: usize) -> Vec<JobDesc> {
    let artifacts = Path::new("artifacts");
    let mut hyd = shard::Hydrator::new(artifacts);
    let models = ["synth:tiny:3", "synth:lenet:5", "synth:residual:7"];
    let mut per_model: Vec<Vec<JobDesc>> = Vec::new();
    for (mi, model) in models.iter().enumerate() {
        let spec = marvel::models::resolve(artifacts, model).unwrap();
        let mut rng = Rng::new(500 + mi as u64);
        let mut descs = Vec::new();
        for v in [V0, V4] {
            let (c, _) = hyd.hydrate(model, v.name).unwrap();
            for _ in 0..n_inputs {
                let input = marvel::models::synth::Builder::random_input(
                    &spec, &mut rng,
                );
                let packed = pack_input(&input).unwrap();
                descs.push(shard::desc_for(model, &c, &packed, 1 << 33));
            }
        }
        per_model.push(descs);
    }
    let mut out = Vec::new();
    let longest = per_model.iter().map(Vec::len).max().unwrap();
    for i in 0..longest {
        for m in &per_model {
            if let Some(d) = m.get(i) {
                out.push(d.clone());
            }
        }
    }
    out
}

/// load x1 <- dm[0]; x1 += 1; store dm[4] <- x1; ecall
fn add_one_program() -> Arc<Program> {
    Arc::new(
        Program::from_instrs(
            V0,
            vec![
                Instr::Load { op: LoadOp::Lb, rd: 1, rs1: 0, offset: 0 },
                Instr::OpImm { op: AluImmOp::Addi, rd: 1, rs1: 1, imm: 1 },
                Instr::Store { op: StoreOp::Sb, rs2: 1, rs1: 0, offset: 4 },
                Instr::Ecall,
            ],
        )
        .unwrap(),
    )
}

fn raw_add_job(x: u8, dm_size: usize) -> RawJob {
    RawJob {
        program: add_one_program(),
        dm_size,
        base_image: None,
        preload: Vec::new(),
        input: (0, vec![x]),
        output: (4, 1),
        max_instrs: 100,
    }
}

/// Checks 1–3: every backend, twice (the second round proves persistent
/// state never leaks into results), against the in-process reference —
/// including an erroring job pinned mid-batch.
#[test]
fn every_backend_matches_reference_bit_for_bit() {
    let mut descs = zoo_descs(2);
    // One failing job mid-batch: an absurd watchdog budget.  Its error
    // must stay exactly at this index on every backend.
    let mut starved = descs[0].clone();
    starved.max_instrs = 1;
    descs.insert(3, starved);
    let reference = run_descs_local(Path::new("artifacts"), &descs, 0);
    assert!(reference[3].is_err(), "the starved job must fail");

    for mut exec in backends() {
        let name = exec.describe();
        assert!(exec.caps().persistent_pool, "{name}: pools persist");
        assert!(
            exec.caps().parallelism >= 1,
            "{name}: a backend always has at least one lane"
        );
        for round in 0..2 {
            for (i, d) in descs.iter().enumerate() {
                assert_eq!(
                    exec.submit(JobSpec::named(d.clone())),
                    i,
                    "{name}: submit returns the submission index"
                );
            }
            let got = exec.run();
            assert_eq!(got.len(), reference.len(), "{name} round {round}");
            for (i, (g, r)) in got.iter().zip(&reference).enumerate() {
                match (g, r) {
                    (Ok(g), Ok(r)) => {
                        assert_eq!(
                            g.output, r.output,
                            "{name} round {round} job {i}: logits diverged"
                        );
                        assert_eq!(
                            g.stats, r.stats,
                            "{name} round {round} job {i}: stats diverged"
                        );
                    }
                    (Err(_), Err(_)) => {}
                    (g, r) => panic!(
                        "{name} round {round} job {i}: {g:?} vs {r:?}"
                    ),
                }
            }
        }
    }
}

/// A lazily-hydrated spec (wire description only) and an eagerly-hydrated
/// one (submitter's compilation attached) are the same job.
#[test]
fn lazy_and_eager_hydration_agree() {
    let artifacts = Path::new("artifacts");
    let descs = zoo_descs(1);
    let reference = run_descs_local(artifacts, &descs, 0);
    let mut hyd = shard::Hydrator::new(artifacts);
    let mut exec = LocalExec::new(artifacts, 2);
    for d in &descs {
        let (c, n) = hyd.hydrate(&d.model, &d.variant).unwrap();
        exec.submit(JobSpec::hydrated(
            &d.model, &c, n, &d.input, d.max_instrs,
        ));
    }
    for (i, (g, r)) in exec.run().iter().zip(&reference).enumerate() {
        assert_eq!(g.as_ref().unwrap(), r.as_ref().unwrap(), "job {i}");
    }
}

/// Check 2 (hydration flavor): an unresolvable model is a per-job error
/// at its index on every backend, never a batch failure.
#[test]
fn hydration_failure_stays_at_its_index_on_every_backend() {
    let mut descs = zoo_descs(1);
    let mut unknown = descs[0].clone();
    unknown.model = "synth:nope:1".into();
    descs.insert(1, unknown);
    let reference = run_descs_local(Path::new("artifacts"), &descs, 0);

    for mut exec in backends() {
        let name = exec.describe();
        for d in &descs {
            exec.submit(JobSpec::named(d.clone()));
        }
        let got = exec.run();
        match &got[1] {
            Err(SimError::Remote { msg, .. }) => {
                assert!(msg.contains("synth:nope"), "{name}: {msg}")
            }
            other => panic!("{name}: expected hydration error, got {other:?}"),
        }
        for (i, (g, r)) in got.iter().zip(&reference).enumerate() {
            if i == 1 {
                continue;
            }
            assert_eq!(
                g.as_ref().unwrap(),
                r.as_ref().unwrap(),
                "{name} job {i}"
            );
        }
    }
}

/// The parallelism capability (DESIGN.md §14's batch-sizing hint) tracks
/// each backend's actual concurrent-lane count.
#[test]
fn parallelism_capability_matches_backend_shape() {
    let local = LocalExec::new(Path::new("artifacts"), 3);
    assert_eq!(local.caps().parallelism, 3);
    let shard = ShardExec::from_pool(
        ShardPool::spawn(&marvel_worker_cmd(), 2).unwrap(),
        2,
    );
    assert_eq!(
        shard.caps().parallelism,
        2 * marvel::sim::shard::PIPELINE,
        "a shard's lanes are workers x pipeline depth"
    );
}

/// Lane packing (DESIGN.md §15) is invisible through the Executor seam:
/// the same model-interleaved batch produces bit-identical results, in
/// submission order, whether the local backend runs scalar
/// (`set_lanes(1)`), packs up to 8 lanes, or the batch goes through the
/// scalar-off-the-wire shard backend.
#[test]
fn lane_packing_is_invisible_across_backends() {
    let descs = zoo_descs(3);
    let reference = run_descs_local(Path::new("artifacts"), &descs, 0);

    let mut runs = Vec::new();
    for lanes in [1usize, 8] {
        let mut exec = LocalExec::new(Path::new("artifacts"), 2);
        exec.set_lanes(lanes);
        assert_eq!(exec.caps().lanes, lanes);
        for d in &descs {
            exec.submit(JobSpec::named(d.clone()));
        }
        runs.push((format!("local:2 lanes:{lanes}"), exec.run()));
    }
    let mut shard = ShardExec::from_pool(
        ShardPool::spawn(&marvel_worker_cmd(), 2).unwrap(),
        2,
    );
    assert_eq!(shard.caps().lanes, 1, "shard workers run scalar");
    for d in &descs {
        shard.submit(JobSpec::named(d.clone()));
    }
    runs.push(("shard:2".to_string(), shard.run()));

    for (name, got) in &runs {
        assert_eq!(got.len(), reference.len(), "{name}");
        for (i, (g, r)) in got.iter().zip(&reference).enumerate() {
            assert_eq!(
                g.as_ref().unwrap(),
                r.as_ref().unwrap(),
                "{name} job {i}: lane packing must be invisible"
            );
        }
    }
}

/// Superinstruction fusion (DESIGN.md §19) is invisible through the
/// Executor seam, exactly like lane packing: with `MARVEL_SUPEROPS=1` and
/// an 8-lane local backend, the model-interleaved batch — conv inner
/// loops full of fusible straight-line runs — is bit-identical, logits
/// and `RunStats` both, to the scalar fusion-off reference.
#[test]
fn superops_with_lane_packing_matches_scalar_reference() {
    let descs = zoo_descs(2);
    // Reference first, before fusion is switched on for this process
    // (fusion on would still be bit-identical — that is the invariant —
    // but the cell is only a differential if the two sides differ in
    // execution shape).
    let reference = run_descs_local(Path::new("artifacts"), &descs, 0);
    std::env::set_var("MARVEL_SUPEROPS", "1");
    let got = {
        let mut exec = LocalExec::new(Path::new("artifacts"), 2);
        exec.set_lanes(8);
        for d in &descs {
            exec.submit(JobSpec::named(d.clone()));
        }
        exec.run()
    };
    std::env::remove_var("MARVEL_SUPEROPS");
    assert_eq!(got.len(), reference.len());
    for (i, (g, r)) in got.iter().zip(&reference).enumerate() {
        assert_eq!(
            g.as_ref().unwrap(),
            r.as_ref().unwrap(),
            "job {i}: superops + lane packing must be invisible"
        );
    }
}

/// Check 4, local flavor: a job that panics its worker thread (DM resize
/// capacity overflow — a bug class, not a `SimError`) panics the caller.
#[test]
fn poison_job_panics_local_backend() {
    let mut exec = LocalExec::new(Path::new("artifacts"), 2);
    exec.submit(JobSpec::raw(raw_add_job(1, 64)));
    exec.submit(JobSpec::raw(raw_add_job(2, usize::MAX)));
    exec.submit(JobSpec::raw(raw_add_job(3, 64)));
    let r = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
        exec.run()
    }));
    assert!(r.is_err(), "local poison job must panic the caller");
}

/// Check 4, shard flavor: a pool whose workers keep dying on every job
/// (respawn budget included) propagates as a panic, mirroring the
/// in-process contract.
#[test]
fn poison_job_panics_shard_backend() {
    let cmd = WorkerCmd {
        program: PathBuf::from("/bin/sh"),
        envs: Vec::new(),
        args: vec![
            "-c".to_string(),
            "echo '{\"type\":\"ready\",\"version\":\"stub\"}'; read line; \
             exit 1"
                .to_string(),
        ],
    };
    let mut exec =
        ShardExec::from_pool(ShardPool::spawn(&cmd, 2).unwrap(), 2);
    for d in zoo_descs(1).into_iter().take(2) {
        exec.submit(JobSpec::named(d));
    }
    let r = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
        exec.run()
    }));
    assert!(r.is_err(), "shard poison job must panic the caller");
}

/// Cluster recovery, dead-host flavor: one of two daemon *processes* is
/// killed outright — its connection drops, every re-dial is refused, the
/// slot is retired — and the sweep completes bit-identically on the
/// survivor.
#[test]
fn cluster_dead_host_falls_back_to_survivors() {
    let descs = zoo_descs(2);
    let reference = run_descs_local(Path::new("artifacts"), &descs, 0);
    let mut exec = cluster_exec(2);
    assert_eq!(exec.pool().live_hosts(), 2);
    exec.loopback_mut().unwrap().kill_host(0);
    for d in &descs {
        exec.submit(JobSpec::named(d.clone()));
    }
    let got = exec.run();
    assert_eq!(got.len(), reference.len());
    for (i, (g, r)) in got.iter().zip(&reference).enumerate() {
        assert_eq!(g.as_ref().unwrap(), r.as_ref().unwrap(), "job {i}");
    }
    assert_eq!(exec.pool().live_hosts(), 1, "the dead slot stays retired");
    assert_eq!(
        exec.pool().redials_used(),
        0,
        "a dead host never re-dials successfully"
    );
}

/// Cluster recovery, session flavor: a chaos plan kills the host's
/// *connection* mid-sweep (the daemon process survives), the pool
/// re-dials it, and the sweep finishes bit-identically — the mid-sweep
/// reconnect path.
#[test]
fn cluster_chaos_kill_reconnects_mid_sweep() {
    let descs = zoo_descs(2);
    assert!(descs.len() > 4, "the kill at wire seq 3 must land mid-batch");
    let reference = run_descs_local(Path::new("artifacts"), &descs, 0);
    let plan = FaultPlan::parse("worker:kill@3").unwrap();
    let mut exec = ClusterExec::spawn_loopback_cmd(
        Path::new(env!("CARGO_BIN_EXE_marvel")),
        Path::new("artifacts"),
        1,
        Some(&plan),
    )
    .unwrap();
    for d in &descs {
        exec.submit(JobSpec::named(d.clone()));
    }
    let got = exec.run();
    for (i, (g, r)) in got.iter().zip(&reference).enumerate() {
        assert_eq!(g.as_ref().unwrap(), r.as_ref().unwrap(), "job {i}");
    }
    assert!(
        exec.pool().redials_used() >= 1,
        "the chaos kill must force a mid-sweep re-dial"
    );
    assert_eq!(
        exec.pool().live_hosts(),
        1,
        "the daemon survives its killed session"
    );
}

/// Check 5, cluster flavor: capability shape and the raw-job refusal at
/// its index.
#[test]
fn cluster_raw_refusal_and_caps() {
    let descs = zoo_descs(1);
    let reference = run_descs_local(Path::new("artifacts"), &descs[..1], 0);
    let mut exec = cluster_exec(1);
    assert!(exec.caps().cross_process);
    assert!(exec.caps().persistent_pool);
    assert_eq!(
        exec.caps().parallelism,
        marvel::sim::shard::PIPELINE,
        "a cluster's lanes are hosts x pipeline depth"
    );
    assert_eq!(exec.describe(), "cluster:1");
    exec.submit(JobSpec::named(descs[0].clone()));
    exec.submit(JobSpec::raw(raw_add_job(41, 64)));
    let rs = exec.run();
    assert_eq!(rs[0].as_ref().unwrap(), reference[0].as_ref().unwrap());
    match &rs[1] {
        Err(SimError::Remote { msg, .. }) => {
            assert!(msg.contains("cross-process"), "{msg}")
        }
        other => panic!("expected capability refusal, got {other:?}"),
    }
}

/// Check 5: raw memory-image jobs run in-process but a `cross_process`
/// backend refuses them at their index — named neighbors still run.
#[test]
fn raw_jobs_refused_by_cross_process_backend() {
    let descs = zoo_descs(1);
    let reference = run_descs_local(Path::new("artifacts"), &descs[..2], 0);

    // In-process: the raw job simply runs.
    let mut local = LocalExec::new(Path::new("artifacts"), 2);
    assert!(!local.caps().cross_process);
    local.submit(JobSpec::raw(raw_add_job(41, 64)));
    assert_eq!(local.run()[0].as_ref().unwrap().output, vec![42]);

    // Cross-process: refused at its index, neighbors unharmed.
    let mut exec = ShardExec::from_pool(
        ShardPool::spawn(&marvel_worker_cmd(), 1).unwrap(),
        1,
    );
    assert!(exec.caps().cross_process);
    exec.submit(JobSpec::named(descs[0].clone()));
    exec.submit(JobSpec::raw(raw_add_job(41, 64)));
    exec.submit(JobSpec::named(descs[1].clone()));
    let rs = exec.run();
    assert_eq!(rs[0].as_ref().unwrap(), reference[0].as_ref().unwrap());
    match &rs[1] {
        Err(SimError::Remote { msg, .. }) => {
            assert!(msg.contains("cross-process"), "{msg}")
        }
        other => panic!("expected capability refusal, got {other:?}"),
    }
    assert_eq!(rs[2].as_ref().unwrap(), reference[1].as_ref().unwrap());
}
