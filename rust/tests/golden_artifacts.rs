//! Artifact-dependent integration: ties the Python (L1/L2) and Rust (L3)
//! halves together through the exported artifacts.
//!
//! Requires `make artifacts` (skips with a message otherwise, so plain
//! `cargo test` stays green in a fresh checkout).  The chain validated here:
//!
//!   jnp oracle (ref.py) ──export──> golden y.bin
//!        │                             ║ must equal
//!   pallas kernels ──AOT HLO──> PJRT execution
//!        │                             ║ must equal
//!   spec JSON ──rust compiler──> RV32 code on the ISS (all 5 variants)
//!        │                             ║ must equal
//!        └──────> rust refexec ────────╝

use std::path::{Path, PathBuf};

use marvel::compiler::{compile, execute_compiled};
use marvel::coordinator::{run_flow, FlowOptions};
use marvel::models;
use marvel::refexec;
use marvel::runtime;
use marvel::sim::{NopHook, VARIANTS};

fn artifacts() -> Option<PathBuf> {
    let p = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if p.join("models").join("lenet5.json").exists() {
        Some(p)
    } else {
        eprintln!("SKIP: artifacts not built (run `make artifacts`)");
        None
    }
}

#[test]
fn lenet_iss_matches_exported_golden_all_variants() {
    let Some(arts) = artifacts() else { return };
    let spec = models::load(&arts, "lenet5").unwrap();
    let io = runtime::load_golden_io(&arts, "lenet5").unwrap();
    for v in VARIANTS {
        let c = compile(&spec, v).unwrap();
        for (x, y) in io.inputs.iter().zip(&io.outputs) {
            let (got, _) =
                execute_compiled(&c, &spec, x, 1 << 36, &mut NopHook).unwrap();
            assert_eq!(&got, y, "lenet5 on {}", v.name);
        }
    }
}

#[test]
fn refexec_matches_exported_golden_for_all_models() {
    let Some(arts) = artifacts() else { return };
    for (name, spec) in models::load_available(&arts) {
        let io = runtime::load_golden_io(&arts, &name).unwrap();
        for (x, y) in io.inputs.iter().zip(&io.outputs) {
            let got = refexec::run(&spec, x).unwrap();
            assert_eq!(&got, y, "{name}");
        }
    }
}

#[test]
fn pjrt_hlo_artifact_matches_refexec() {
    let Some(arts) = artifacts() else { return };
    let rt = match runtime::Runtime::cpu() {
        Ok(rt) => rt,
        Err(e) => panic!("PJRT CPU client unavailable: {e}"),
    };
    // lenet (trained) + the smallest tool-built model keep this test fast
    for name in ["lenet5", "mobilenet_v1"] {
        let Ok(spec) = models::load(&arts, name) else { continue };
        let io = runtime::load_golden_io(&arts, name).unwrap();
        let g = rt
            .load_model(&arts, name, spec.input_shape, spec.output_elems())
            .unwrap();
        for (x, y) in io.inputs.iter().zip(&io.outputs) {
            let got = g.run(x).unwrap();
            assert_eq!(&got, y, "{name} PJRT vs exported");
            assert_eq!(got, refexec::run(&spec, x).unwrap(), "{name} PJRT vs refexec");
        }
    }
}

#[test]
fn flow_headline_speedup_on_trained_lenet() {
    let Some(arts) = artifacts() else { return };
    let f = run_flow(&arts, "lenet5", &FlowOptions::default()).unwrap();
    assert!(f.verified_golden);
    let v4 = f.metrics.last().unwrap();
    // the paper's headline: up to 2x inference speedup and 2x energy
    assert!(v4.speedup > 2.0, "speedup {}", v4.speedup);
    let e0 = f.metrics[0].energy.energy_mj;
    assert!(e0 / v4.energy.energy_mj > 2.0);
    // ladder is monotone
    for w in f.metrics.windows(2) {
        assert!(w[1].cycles <= w[0].cycles);
    }
}

#[test]
fn memory_table_trends_hold() {
    let Some(arts) = artifacts() else { return };
    // PM shrinks monotonically v0 -> v4 for every model (Table 10 trend);
    // DM is variant-invariant by planner construction.
    for (name, spec) in models::load_available(&arts) {
        let mut last_pm = u32::MAX;
        let mut dm = None;
        for v in VARIANTS {
            let c = compile(&spec, v).unwrap();
            assert!(c.pm_bytes() <= last_pm, "{name} {} PM grew", v.name);
            last_pm = c.pm_bytes();
            let d = *dm.get_or_insert(c.dm_bytes());
            assert_eq!(d, c.dm_bytes(), "{name} DM varies by variant");
        }
    }
}
