//! Cross-module integration tests that need no artifacts: spec JSON round
//! trip through the real parser, profiler → extgen → rebuild loop, flow
//! invariants on synthetic models, and energy/area accounting.

use std::collections::BTreeMap;

use marvel::compiler::spec::{parse_spec, Dtype};
use marvel::compiler::{compile, execute_compiled};
use marvel::extgen;
use marvel::hw::{area_of, energy_mj};
use marvel::models::synth::{residual_net, tiny_conv_net, Builder};
use marvel::profiler::ProfileHook;
use marvel::refexec;
use marvel::sim::{NopHook, V0, V4, VARIANTS};
use marvel::util::json::{ObjBuilder, Value};
use marvel::util::rng::Rng;

/// Build the exporter's JSON + blob for a hand-written two-layer model and
/// push it through the real spec parser (the exact python/export.py format).
#[test]
fn spec_json_blob_roundtrip_through_parser() {
    // conv: 1x1, ic=1, oc=1, w=[[2]], b=[3]; dense: 4->2
    let mut blob: Vec<u8> = Vec::new();
    blob.push(2i8 as u8); // t0: conv w (i8)
    blob.extend_from_slice(&3i32.to_le_bytes()); // t1: conv b (i32)
    let dw: [i8; 8] = [1, 0, 0, 0, 0, 1, 0, 0]; // t2: dense w (2x4 i8)
    for v in dw {
        blob.push(v as u8);
    }
    blob.extend_from_slice(&0i32.to_le_bytes()); // t3[0]
    blob.extend_from_slice(&(-1i32).to_le_bytes()); // t3[1]

    let tensors = vec![
        ObjBuilder::new().set("name", "t0").set("dtype", "i8")
            .set("shape", vec![1i64, 1, 1, 1]).set("offset", 0i64)
            .set("size", 1i64).build(),
        ObjBuilder::new().set("name", "t1").set("dtype", "i32")
            .set("shape", vec![1i64]).set("offset", 1i64).set("size", 1i64)
            .build(),
        ObjBuilder::new().set("name", "t2").set("dtype", "i8")
            .set("shape", vec![2i64, 4]).set("offset", 5i64).set("size", 8i64)
            .build(),
        ObjBuilder::new().set("name", "t3").set("dtype", "i32")
            .set("shape", vec![2i64]).set("offset", 13i64).set("size", 2i64)
            .build(),
    ];
    let layers = vec![
        ObjBuilder::new()
            .set("op", "conv2d")
            .set("inputs", vec![-1i64])
            .set("w", "t0").set("b", "t1")
            .set("stride", 1i64).set("pad", 0i64).set("shift", 1i64)
            .set("relu", false)
            .set("in_shape", vec![1i64, 2, 2])
            .set("out_shape", vec![1i64, 2, 2])
            .build(),
        ObjBuilder::new()
            .set("op", "dense")
            .set("inputs", vec![0i64])
            .set("w", "t2").set("b", "t3")
            .set("shift", 0i64).set("relu", false)
            .set("in_len", 4i64)
            .set("out_shape", vec![2i64])
            .build(),
    ];
    let doc = ObjBuilder::new()
        .set("name", "handmade")
        .set("profile", "test")
        .set("input_shape", vec![1i64, 2, 2])
        .set("num_classes", 2i64)
        .set("layers", Value::Arr(layers))
        .set("tensors", Value::Arr(tensors))
        .build();

    let spec = parse_spec(&doc.to_string(), &blob).expect("parse");
    assert_eq!(spec.name, "handmade");
    assert_eq!(spec.tensors["t0"].dtype, Dtype::I8);
    assert_eq!(spec.tensors["t0"].data, vec![2]);
    assert_eq!(spec.tensors["t3"].data, vec![0, -1]);

    // semantics: x -> conv acc 2x+3, requant shift 1 -> dense picks [0], [1]-1
    let x = vec![10, -6, 3, 0];
    let y = refexec::run(&spec, &x).unwrap();
    assert_eq!(y, vec![12, -5]);

    // and through the full compile→simulate path on every variant
    for v in VARIANTS {
        let c = compile(&spec, v).unwrap();
        let (got, _) =
            execute_compiled(&c, &spec, &x, 1 << 20, &mut NopHook).unwrap();
        assert_eq!(got, y, "{}", v.name);
    }
}

/// The paper's full methodology loop on a synthetic model: profile v0 →
/// extgen proposes all four extensions → the built v4 realizes savings in
/// the predicted direction.
#[test]
fn profile_propose_rebuild_loop() {
    let spec = tiny_conv_net(77);
    let mut rng = Rng::new(8);
    let input = Builder::random_input(&spec, &mut rng);

    let c0 = compile(&spec, V0).unwrap();
    let mut hook = ProfileHook::new(c0.words().len());
    let (_, s0) =
        execute_compiled(&c0, &spec, &input, 1 << 32, &mut hook).unwrap();

    let counts = hook.finish();
    let proposals = extgen::propose(&counts, 0.002);
    let names: Vec<_> = proposals.iter().map(|p| p.name).collect();
    for n in ["mac", "add2i", "fusedmac", "zol"] {
        assert!(names.contains(&n), "missing proposal {n} in {names:?}");
    }

    let c4 = compile(&spec, V4).unwrap();
    let (out4, s4) =
        execute_compiled(&c4, &spec, &input, 1 << 32, &mut NopHook).unwrap();
    assert_eq!(out4, refexec::run(&spec, &input).unwrap());
    assert!(s4.cycles < s0.cycles);

    for p in &proposals {
        assert!(p.savings_frac > 0.0 && p.savings_frac < 1.0);
        assert!(p.cycles_after < p.cycles_before);
    }
}

/// Energy/area accounting: E = P*C/f with the Table 8 powers; the variant
/// ladder strictly reduces energy on a conv-heavy workload.
#[test]
fn energy_area_accounting_consistent() {
    let spec = residual_net(5);
    let mut rng = Rng::new(9);
    let input = Builder::random_input(&spec, &mut rng);
    let mut last_energy = f64::INFINITY;
    for v in VARIANTS {
        let c = compile(&spec, v).unwrap();
        let (_, stats) =
            execute_compiled(&c, &spec, &input, 1 << 32, &mut NopHook).unwrap();
        let e = energy_mj(&v, stats.cycles);
        let a = area_of(&v);
        let want = a.power_mw * stats.cycles as f64 / 1e8;
        assert!((e.energy_mj - want).abs() < 1e-9);
        assert!(
            e.energy_mj < last_energy,
            "{}: {} !< {}",
            v.name,
            e.energy_mj,
            last_energy
        );
        last_energy = e.energy_mj;
    }
}

/// Two inferences on fresh sims are identical — no state leaks.
#[test]
fn repeated_inference_deterministic() {
    let spec = tiny_conv_net(123);
    let mut rng = Rng::new(3);
    let input = Builder::random_input(&spec, &mut rng);
    let c = compile(&spec, V4).unwrap();
    let (a, sa) =
        execute_compiled(&c, &spec, &input, 1 << 32, &mut NopHook).unwrap();
    let (b, sb) =
        execute_compiled(&c, &spec, &input, 1 << 32, &mut NopHook).unwrap();
    assert_eq!(a, b);
    assert_eq!(sa, sb);
}

/// Profiler cycle accounting must equal the simulator's RunStats.
#[test]
fn profiler_cycles_match_runstats() {
    let spec = tiny_conv_net(55);
    let mut rng = Rng::new(4);
    let input = Builder::random_input(&spec, &mut rng);
    let c = compile(&spec, V0).unwrap();
    let mut hook = ProfileHook::new(c.words().len());
    let (_, stats) =
        execute_compiled(&c, &spec, &input, 1 << 32, &mut hook).unwrap();
    assert_eq!(hook.counts.total, stats.instrs);
    assert_eq!(hook.counts.cycles, stats.cycles);
    let pc_total: u64 = hook.pc_cycles.iter().sum();
    assert_eq!(pc_total, stats.cycles);
}

/// Malformed spec inputs must fail with errors, not panics or silence.
#[test]
fn malformed_specs_rejected() {
    // valid skeleton to mutate
    let ok = r#"{"name":"m","input_shape":[1,2,2],"num_classes":2,
        "layers":[{"op":"dense","inputs":[-1],"w":"t0","b":"t1","shift":0,
                   "relu":false,"in_len":4,"out_shape":[2]}],
        "tensors":[{"name":"t0","dtype":"i8","shape":[2,4],"offset":0,"size":8},
                   {"name":"t1","dtype":"i32","shape":[2],"offset":8,"size":2}]}"#;
    let blob = vec![0u8; 16];
    assert!(parse_spec(ok, &blob).is_ok());

    // blob too small for the declared tensors
    assert!(parse_spec(ok, &blob[..4]).is_err());
    // unknown op
    let bad = ok.replace("\"dense\"", "\"softmax\"");
    assert!(parse_spec(&bad, &blob).is_err());
    // unknown dtype
    let bad = ok.replace("\"i32\"", "\"f32\"");
    assert!(parse_spec(&bad, &blob).is_err());
    // shape/size mismatch
    let bad = ok.replace("\"size\":8", "\"size\":7");
    assert!(parse_spec(&bad, &blob).is_err());
    // dangling input index
    let bad = ok.replace("\"inputs\":[-1]", "\"inputs\":[5]");
    assert!(parse_spec(&bad, &blob).is_err());
    // truncated JSON
    assert!(parse_spec(&ok[..ok.len() - 3], &blob).is_err());
}

/// Custom cycle models flow through the whole stack (a slower multiplier
/// must raise cycle counts but never change outputs).
#[test]
fn custom_cycle_model_affects_cycles_not_outputs() {
    use marvel::compiler::{load_input, make_sim, read_output};
    let spec = tiny_conv_net(31);
    let mut rng = Rng::new(12);
    let input = Builder::random_input(&spec, &mut rng);
    let c = compile(&spec, V0).unwrap();
    let run_with = |mul_cost: u64| {
        let mut sim = make_sim(&c).unwrap();
        sim.cycle_model.mul = mul_cost;
        load_input(&mut sim, &c, &input).unwrap();
        let stats = sim.run_fast(1 << 32).unwrap();
        let out = read_output(&sim, &c, spec.output_elems()).unwrap();
        (out, stats)
    };
    let (out1, fast) = run_with(1);
    let (out4, slow) = run_with(4);
    assert_eq!(out1, out4);
    assert!(slow.cycles > fast.cycles);
    assert_eq!(slow.instrs, fast.instrs);
}

/// JSON emitted by our writer parses back to the identical value.
#[test]
fn json_writer_parser_fixpoint() {
    let v = ObjBuilder::new()
        .set("models", vec!["lenet5", "vgg16"])
        .set("speedup", 2.48f64)
        .set("cycles", 1_169_634i64)
        .set(
            "nested",
            Value::Arr(vec![
                ObjBuilder::new().set("a", Value::Null).set("b", false).build(),
            ]),
        )
        .build();
    let text = v.to_string();
    let back = marvel::util::json::parse(&text).unwrap();
    assert_eq!(back, v);
    let map: &BTreeMap<String, Value> = back.as_obj().unwrap();
    assert_eq!(map["speedup"].as_f64().unwrap(), 2.48);
}
