//! Differential tests: the lowered micro-op interpreter (`Machine::run`)
//! against the reference decode-enum interpreter
//! (`Machine::run_reference`), which survives precisely to be this oracle
//! (DESIGN.md §11), plus the two execution shapes layered on the lowered
//! form (DESIGN.md §15): `threaded ≡ match` (direct-threaded dispatch vs
//! the original central match) and `lanes ≡ reference` (multi-lane groups
//! vs per-lane scalar reference runs).
//!
//! The contract is *bit-identical observable behaviour*: same
//! `Result<RunStats, SimError>` (including the exact fault and pc), same
//! registers / pc / ZOL registers / data memory after the run, and the
//! same retire-hook stream (pc, instruction, cycle cost per retirement).
//!
//! The superinstruction-fusion suite (DESIGN.md §19) holds the fused
//! lowering (`Machine::superops = true`) to the same contract: fused
//! scalar runs, fused `run_match` dispatch, fused lane groups over mined
//! `v4+x<mask>` variants, and observing-hook runs (which decay fused
//! slots back to scalar) must all be indistinguishable from the
//! reference interpreter.

use std::sync::Arc;

use marvel::compiler::{compile, execute_compiled};
use marvel::isa::random_instr;
use marvel::models::synth::{lenet_shaped, Builder};
use marvel::sim::{CycleModel, Machine, NopHook, Program, TraceHook, Variant,
                  RunStats, SimError, V4, VARIANTS};
use marvel::util::proptest::check;
use marvel::util::rng::Rng;

const DM_SIZE: usize = 4096;
const MAX_INSTRS: u64 = 3_000;

/// A random program of supported instructions for `variant`.
fn random_program(rng: &mut Rng, variant: Variant) -> Arc<Program> {
    let len = rng.range_usize(1, 48);
    let mut instrs = Vec::with_capacity(len);
    while instrs.len() < len {
        let i = random_instr(rng);
        if variant.supports(&i) {
            instrs.push(i);
        }
    }
    Arc::new(Program::from_instrs(variant, instrs).unwrap())
}

/// Seed both machines with identical, partly-memory-friendly registers so
/// loads/stores sometimes land in bounds.
fn seed_regs(rng: &mut Rng) -> [i32; 32] {
    let mut regs = [0i32; 32];
    for r in regs.iter_mut().skip(1) {
        *r = if rng.bool() {
            rng.int_in(0, (DM_SIZE as i32 / 4) - 1) * 4
        } else {
            rng.int_in(i32::MIN / 2, i32::MAX / 2)
        };
    }
    regs
}

/// Everything one run exposes: the result, the final machine state, and
/// the retire trace.
type RunOutcome = (Result<RunStats, SimError>, Machine, Vec<String>);

fn run_both(
    program: &Arc<Program>,
    regs: [i32; 32],
    max_instrs: u64,
) -> (RunOutcome, RunOutcome) {
    let mut run_one = |reference: bool| {
        let mut m = Machine::new(Arc::clone(program), DM_SIZE);
        m.regs = regs;
        let mut trace = TraceHook::new(256);
        let r = if reference {
            m.run_reference(max_instrs, &mut trace)
        } else {
            m.run(max_instrs, &mut trace)
        };
        (r, m, trace.lines)
    };
    (run_one(true), run_one(false))
}

/// Like [`run_both`] but pitting the two *lowered* dispatch shapes against
/// each other: the kept central-`match` loop (`Machine::run_match`, the
/// oracle here) vs direct-threaded dispatch (`Machine::run`).
fn run_both_dispatch(
    program: &Arc<Program>,
    regs: [i32; 32],
    max_instrs: u64,
) -> (RunOutcome, RunOutcome) {
    let mut run_one = |match_dispatch: bool| {
        let mut m = Machine::new(Arc::clone(program), DM_SIZE);
        m.regs = regs;
        let mut trace = TraceHook::new(256);
        let r = if match_dispatch {
            m.run_match(max_instrs, &mut trace)
        } else {
            m.run(max_instrs, &mut trace)
        };
        (r, m, trace.lines)
    };
    (run_one(true), run_one(false))
}

fn diff(
    label: &str,
    (ref_r, ref_m, ref_t): RunOutcome,
    (low_r, low_m, low_t): RunOutcome,
) -> Result<(), String> {
    let (ref_s, low_s) = (format!("{ref_r:?}"), format!("{low_r:?}"));
    if ref_s != low_s {
        return Err(format!("{label}: result mismatch\n ref: {ref_s}\n low: {low_s}"));
    }
    if ref_m.regs != low_m.regs {
        return Err(format!(
            "{label}: register mismatch\n ref: {:?}\n low: {:?}",
            ref_m.regs, low_m.regs
        ));
    }
    if ref_m.pc != low_m.pc {
        return Err(format!(
            "{label}: pc mismatch ref={:#x} low={:#x}",
            ref_m.pc, low_m.pc
        ));
    }
    if (ref_m.zc, ref_m.zs, ref_m.ze) != (low_m.zc, low_m.zs, low_m.ze) {
        return Err(format!(
            "{label}: zol mismatch ref=({},{},{}) low=({},{},{})",
            ref_m.zc, ref_m.zs, ref_m.ze, low_m.zc, low_m.zs, low_m.ze
        ));
    }
    let ref_mem = ref_m.mem.read_block(0, ref_m.mem.len()).unwrap();
    let low_mem = low_m.mem.read_block(0, low_m.mem.len()).unwrap();
    if ref_mem != low_mem {
        return Err(format!("{label}: data memory diverged"));
    }
    if ref_t != low_t {
        return Err(format!(
            "{label}: retire trace mismatch\n ref: {:?}\n low: {:?}",
            ref_t, low_t
        ));
    }
    Ok(())
}

/// The central property: for random programs on every variant, the lowered
/// interpreter is indistinguishable from the reference interpreter.
#[test]
fn prop_lowered_matches_reference_on_random_programs() {
    check("lowered ≡ reference (random programs)", 1200, |rng| {
        let variant = *rng.choice(&VARIANTS);
        let program = random_program(rng, variant);
        if program.lowered(&CycleModel::default()).is_none() {
            return Err(format!(
                "{}: random program unexpectedly not lowerable",
                variant.name
            ));
        }
        let regs = seed_regs(rng);
        let (r, l) = run_both(&program, regs, MAX_INSTRS);
        diff(variant.name, r, l)
    });
}

/// Watchdog budgets, including 0, fault identically on both paths.
#[test]
fn prop_lowered_matches_reference_on_tiny_budgets() {
    check("lowered ≡ reference (tiny watchdog)", 300, |rng| {
        let variant = *rng.choice(&VARIANTS);
        let program = random_program(rng, variant);
        let regs = seed_regs(rng);
        let budget = rng.range_usize(0, 12) as u64;
        let (r, l) = run_both(&program, regs, budget);
        diff(variant.name, r, l)
    });
}

/// Deterministic edge cases the random generator rarely hits — shared by
/// the `lowered ≡ reference` and `threaded ≡ match` suites.
fn edge_cases() -> Vec<(&'static str, Variant, Vec<marvel::isa::Instr>)> {
    use marvel::isa::{AluImmOp, BranchOp, Instr};

    vec![
        ("ebreak", V4, vec![Instr::Ebreak]),
        ("fall off the end", V4, vec![Instr::OpImm {
            op: AluImmOp::Addi, rd: 1, rs1: 0, imm: 1,
        }]),
        ("self jump watchdog", V4, vec![Instr::Jal { rd: 0, offset: 0 }]),
        ("branch to misaligned", V4, vec![
            Instr::Branch { op: BranchOp::Beq, rs1: 0, rs2: 0, offset: 6 },
            Instr::Ecall,
        ]),
        ("jalr to oblivion", V4, vec![
            Instr::OpImm { op: AluImmOp::Addi, rd: 1, rs1: 0, imm: 2000 },
            Instr::Jalr { rd: 2, rs1: 1, offset: 1 },
            Instr::Ecall,
        ]),
        // a loop whose ZE is exactly one past the program end: the
        // loop-back must still fire instead of trapping
        ("zol body at program end", V4, vec![
            Instr::Dlpi { count: 3, body_len: 1 },
            Instr::OpImm { op: AluImmOp::Addi, rd: 1, rs1: 1, imm: 1 },
        ]),
        ("zlp zero-count skip", V4, vec![
            Instr::Zlp { rs1: 0, body_len: 2 },
            Instr::OpImm { op: AluImmOp::Addi, rd: 1, rs1: 1, imm: 1 },
            Instr::OpImm { op: AluImmOp::Addi, rd: 1, rs1: 1, imm: 1 },
            Instr::Ecall,
        ]),
        ("zero-length zol body", V4, vec![
            Instr::Dlpi { count: 4, body_len: 0 },
            Instr::Ecall,
        ]),
        ("set registers arm a loop", V4, vec![
            Instr::OpImm { op: AluImmOp::Addi, rd: 1, rs1: 0, imm: 3 },
            Instr::OpImm { op: AluImmOp::Addi, rd: 2, rs1: 0, imm: 12 },
            Instr::OpImm { op: AluImmOp::Addi, rd: 3, rs1: 0, imm: 16 },
            Instr::SetZc { rs1: 1 },
            Instr::SetZs { rs1: 2 },
            Instr::SetZe { rs1: 3 },
            Instr::Ecall,
        ]),
    ]
}

#[test]
fn lowered_matches_reference_on_edge_programs() {
    for (label, variant, instrs) in edge_cases() {
        let program = Arc::new(Program::from_instrs(variant, instrs).unwrap());
        let (r, l) = run_both(&program, [0; 32], 100);
        if let Err(e) = diff(label, r, l) {
            panic!("{e}");
        }
    }
}

/// The threaded handler table is behaviourally the central match, on the
/// same deterministic edge programs.
#[test]
fn threaded_matches_match_on_edge_programs() {
    for (label, variant, instrs) in edge_cases() {
        let program = Arc::new(Program::from_instrs(variant, instrs).unwrap());
        let (m, t) = run_both_dispatch(&program, [0; 32], 100);
        if let Err(e) = diff(label, m, t) {
            panic!("{e}");
        }
    }
}

/// Dispatch differential: the direct-threaded handler table against the
/// kept central-`match` loop, over random programs and watchdog budgets.
#[test]
fn prop_threaded_matches_match_dispatch() {
    check("threaded ≡ match (random programs)", 800, |rng| {
        let variant = *rng.choice(&VARIANTS);
        let program = random_program(rng, variant);
        let regs = seed_regs(rng);
        let budget = if rng.bool() {
            MAX_INSTRS
        } else {
            rng.range_usize(0, 16) as u64
        };
        let (m, t) = run_both_dispatch(&program, regs, budget);
        diff(variant.name, m, t)
    });
}

/// Mined-window differential: random programs on `v4+x<mask>` variants
/// contain `Instr::Custom` window instructions (slot semantics from the
/// `fusion` spec pool); all three execution paths must stay bit-identical
/// on them — reference vs lowered-threaded, and threaded vs central match.
#[test]
fn prop_mined_window_instrs_match_on_all_paths() {
    let full = (1u8 << marvel::fusion::N_WINDOW) - 1;
    check("mined window ≡ on all paths", 600, |rng| {
        let mask = rng.int_in(1, i32::from(full)) as u8;
        let variant = Variant::with_window(V4, mask).unwrap();
        let program = random_program(rng, variant);
        let regs = seed_regs(rng);
        let (r, l) = run_both(&program, regs, MAX_INSTRS);
        diff(variant.name, r, l)?;
        let (m, t) = run_both_dispatch(&program, regs, MAX_INSTRS);
        diff(variant.name, m, t)
    });
}

/// Lane differential: a multi-lane group over one program — per-lane
/// registers, mixed DM sizes, mixed watchdog budgets, divergent early
/// exits — is bit-identical to per-lane scalar reference runs.
#[test]
fn prop_lanes_match_reference() {
    const LANE_DM_SIZES: [usize; 3] = [256, 1024, 4096];
    check("lanes ≡ reference (random groups)", 400, |rng| {
        let variant = *rng.choice(&VARIANTS);
        let program = random_program(rng, variant);
        let k = rng.range_usize(1, 9);
        let mut lanes = Vec::with_capacity(k);
        let mut refs = Vec::with_capacity(k);
        let mut budgets: Vec<u64> = Vec::with_capacity(k);
        for _ in 0..k {
            let dm = *rng.choice(&LANE_DM_SIZES);
            let regs = seed_regs(rng);
            let mut lane = Machine::new(Arc::clone(&program), dm);
            lane.regs = regs;
            let mut reference = Machine::new(Arc::clone(&program), dm);
            reference.regs = regs;
            lanes.push(lane);
            refs.push(reference);
            budgets.push(if rng.bool() {
                MAX_INSTRS
            } else {
                rng.range_usize(0, 24) as u64
            });
        }
        let results = match Machine::run_lane_group(&mut lanes, &budgets) {
            Some(rs) => rs,
            None => {
                return Err(format!(
                    "{}: lane group unexpectedly refused",
                    variant.name
                ))
            }
        };
        for (l, ((lane, mut rm), lr)) in
            lanes.into_iter().zip(refs).zip(results).enumerate()
        {
            let rr = rm.run_reference(budgets[l], &mut NopHook);
            diff(
                &format!("{} lane {l}/{k}", variant.name),
                (rr, rm, Vec::new()),
                (lr, lane, Vec::new()),
            )?;
        }
        Ok(())
    });
}

/// Deterministic divergence: one lane group where lanes exit by every
/// route — immediate `ecall`, misaligned and out-of-bounds data faults,
/// a zero budget, and a self-loop watchdog — each lane retiring
/// individually with exactly its scalar reference behaviour.
#[test]
fn lane_group_with_divergent_exits() {
    use marvel::isa::{BranchOp, Instr, LoadOp};
    let program = Arc::new(
        Program::from_instrs(V4, vec![
            // x1 == 0 -> jump straight to the ecall at pc 12
            Instr::Branch { op: BranchOp::Beq, rs1: 1, rs2: 0, offset: 12 },
            Instr::Load { op: LoadOp::Lw, rd: 2, rs1: 3, offset: 0 },
            Instr::Jal { rd: 0, offset: 0 }, // self-loop -> watchdog
            Instr::Ecall,
        ])
        .unwrap(),
    );
    // (x1, x3, dm_size, budget)
    let setups: [(i32, i32, usize, u64); 8] = [
        (0, 0, 64, 50),        // early ecall
        (1, 1, 64, 50),        // misaligned lw fault
        (1, 0, 256, 50),       // lw ok, then watchdog in the self-loop
        (1, 1 << 20, 64, 50),  // out-of-bounds lw fault
        (0, 0, 256, 0),        // zero budget: watchdog before retiring
        (1, 4, 256, 50),       // lw ok (different address), watchdog
        (0, 0, 64, 50),        // early ecall again
        (1, 2, 64, 50),        // misaligned at a different address
    ];
    let mut lanes: Vec<Machine> = Vec::new();
    let mut refs: Vec<Machine> = Vec::new();
    for &(x1, x3, dm, _) in &setups {
        let mut m = Machine::new(Arc::clone(&program), dm);
        m.regs[1] = x1;
        m.regs[3] = x3;
        let mut r = Machine::new(Arc::clone(&program), dm);
        r.regs[1] = x1;
        r.regs[3] = x3;
        lanes.push(m);
        refs.push(r);
    }
    let budgets: Vec<u64> = setups.iter().map(|s| s.3).collect();
    let results = Machine::run_lane_group(&mut lanes, &budgets)
        .expect("homogeneous group takes the lane path");
    assert_eq!(results.len(), 8);
    for (l, ((lane, mut rm), lr)) in
        lanes.into_iter().zip(refs).zip(results).enumerate()
    {
        let rr = rm.run_reference(budgets[l], &mut NopHook);
        if let Err(e) =
            diff(&format!("lane {l}"), (rr, rm, Vec::new()), (lr, lane, Vec::new()))
        {
            panic!("{e}");
        }
    }
}

/// Entry states the static lowering cannot cover (a manually armed ZE that
/// is not a program loop end) must still behave identically — `run` falls
/// back to the reference loop for them.
#[test]
fn lowered_matches_reference_with_manually_armed_ze() {
    use marvel::isa::{AluImmOp, Instr};
    let program = Arc::new(
        Program::from_instrs(V4, vec![
            Instr::OpImm { op: AluImmOp::Addi, rd: 1, rs1: 1, imm: 1 },
            Instr::OpImm { op: AluImmOp::Addi, rd: 2, rs1: 2, imm: 1 },
            Instr::OpImm { op: AluImmOp::Addi, rd: 3, rs1: 3, imm: 1 },
            Instr::Ecall,
        ])
        .unwrap(),
    );
    let mut run_one = |reference: bool| {
        let mut m = Machine::new(Arc::clone(&program), DM_SIZE);
        m.zc = 2;
        m.zs = 0;
        m.ze = 8; // not a dlp/dlpi/zlp loop end of this program
        let r = if reference {
            m.run_reference(200, &mut NopHook)
        } else {
            m.run(200, &mut NopHook)
        };
        (format!("{r:?}"), m.regs, m.pc, (m.zc, m.zs, m.ze))
    };
    assert_eq!(run_one(true), run_one(false));
}

/// One scalar-reference run with no trace (trace slots empty so [`diff`]
/// still applies).
fn run_scalar_ref(
    program: &Arc<Program>,
    regs: [i32; 32],
    max_instrs: u64,
) -> RunOutcome {
    let mut m = Machine::new(Arc::clone(program), DM_SIZE);
    m.regs = regs;
    let r = m.run_reference(max_instrs, &mut NopHook);
    (r, m, Vec::new())
}

/// One lowered run with superinstruction fusion on.  `NopHook` does not
/// observe retires, so fused slots actually execute fused (an observing
/// hook would decay them to scalar — covered separately below).
fn run_fused(
    program: &Arc<Program>,
    regs: [i32; 32],
    max_instrs: u64,
) -> RunOutcome {
    let mut m = Machine::new(Arc::clone(program), DM_SIZE);
    m.superops = true;
    m.regs = regs;
    let r = m.run(max_instrs, &mut NopHook);
    (r, m, Vec::new())
}

/// Fusion differential: with superops on, random programs on every
/// variant — full and tiny watchdog budgets (a budget can expire mid-run,
/// which must decay the fused head back to scalar) — are bit-identical
/// to the reference interpreter.
#[test]
fn prop_fused_superops_match_reference() {
    check("superops ≡ reference (random programs)", 800, |rng| {
        let variant = *rng.choice(&VARIANTS);
        let program = random_program(rng, variant);
        let regs = seed_regs(rng);
        let budget = if rng.bool() {
            MAX_INSTRS
        } else {
            rng.range_usize(0, 16) as u64
        };
        let r = run_scalar_ref(&program, regs, budget);
        let f = run_fused(&program, regs, budget);
        diff(&format!("{} (fused)", variant.name), r, f)
    });
}

/// Both lowered dispatch shapes agree under fusion: `run_match` shares
/// the fused-execution helper with the threaded handler, and neither may
/// drift from the other.
#[test]
fn prop_fused_threaded_matches_fused_match_dispatch() {
    check("fused threaded ≡ fused match", 400, |rng| {
        let variant = *rng.choice(&VARIANTS);
        let program = random_program(rng, variant);
        let regs = seed_regs(rng);
        let budget = if rng.bool() {
            MAX_INSTRS
        } else {
            rng.range_usize(0, 16) as u64
        };
        let mut run_one = |match_dispatch: bool| {
            let mut m = Machine::new(Arc::clone(&program), DM_SIZE);
            m.superops = true;
            m.regs = regs;
            let r = if match_dispatch {
                m.run_match(budget, &mut NopHook)
            } else {
                m.run(budget, &mut NopHook)
            };
            (r, m, Vec::new())
        };
        diff(
            &format!("{} (fused dispatch)", variant.name),
            run_one(true),
            run_one(false),
        )
    });
}

/// Satellite oracle: random lane groups over mined `v4+x<mask>` variants
/// with fusion on — mixed budgets, mixed DM sizes, custom window
/// instructions interleaved with fusible runs — against per-lane scalar
/// reference runs.  This crosses all three mechanisms: `Kind::Super`
/// slots, the SoA lane loop's converged fused path, and the
/// `FusedCustom`/`Custom` window semantics.
#[test]
fn prop_fused_lane_groups_match_reference_on_mined_variants() {
    const LANE_DM_SIZES: [usize; 3] = [256, 1024, 4096];
    let full = (1u8 << marvel::fusion::N_WINDOW) - 1;
    check("fused lanes ≡ reference (v4+x groups)", 300, |rng| {
        let mask = rng.int_in(1, i32::from(full)) as u8;
        let variant = Variant::with_window(V4, mask).unwrap();
        let program = random_program(rng, variant);
        let k = rng.range_usize(1, 9);
        let mut lanes = Vec::with_capacity(k);
        let mut refs = Vec::with_capacity(k);
        let mut budgets: Vec<u64> = Vec::with_capacity(k);
        for _ in 0..k {
            let dm = *rng.choice(&LANE_DM_SIZES);
            let regs = seed_regs(rng);
            let mut lane = Machine::new(Arc::clone(&program), dm);
            lane.superops = true;
            lane.regs = regs;
            let mut reference = Machine::new(Arc::clone(&program), dm);
            reference.regs = regs;
            lanes.push(lane);
            refs.push(reference);
            budgets.push(if rng.bool() {
                MAX_INSTRS
            } else {
                rng.range_usize(0, 24) as u64
            });
        }
        let results = match Machine::run_lane_group(&mut lanes, &budgets) {
            Some(rs) => rs,
            None => {
                return Err(format!(
                    "{}: fused lane group unexpectedly refused",
                    variant.name
                ))
            }
        };
        for (l, ((lane, mut rm), lr)) in
            lanes.into_iter().zip(refs).zip(results).enumerate()
        {
            let rr = rm.run_reference(budgets[l], &mut NopHook);
            diff(
                &format!("{} fused lane {l}/{k}", variant.name),
                (rr, rm, Vec::new()),
                (lr, lane, Vec::new()),
            )?;
        }
        Ok(())
    });
}

/// An observing hook must see the *scalar* retire stream even with
/// fusion enabled: fused heads decay per step, so the (pc, instr, cost)
/// trace is the reference trace, not one line per superop.
#[test]
fn prop_fused_runs_with_observing_hook_keep_the_retire_trace() {
    check("superops + trace ≡ reference trace", 300, |rng| {
        let variant = *rng.choice(&VARIANTS);
        let program = random_program(rng, variant);
        let regs = seed_regs(rng);
        let mut rm = Machine::new(Arc::clone(&program), DM_SIZE);
        rm.regs = regs;
        let mut rt = TraceHook::new(256);
        let rr = rm.run_reference(MAX_INSTRS, &mut rt);
        let mut fm = Machine::new(Arc::clone(&program), DM_SIZE);
        fm.superops = true;
        fm.regs = regs;
        let mut ft = TraceHook::new(256);
        let fr = fm.run(MAX_INSTRS, &mut ft);
        diff(
            &format!("{} (fused + trace)", variant.name),
            (rr, rm, rt.lines),
            (fr, fm, ft.lines),
        )
    });
}

/// The deterministic edge programs, fused, across a watchdog boundary
/// sweep — budgets that expire before, inside, and after any fused run.
#[test]
fn fused_superops_match_reference_on_edge_programs() {
    for (label, variant, instrs) in edge_cases() {
        let program = Arc::new(Program::from_instrs(variant, instrs).unwrap());
        for budget in [0u64, 1, 2, 3, 4, 5, 100] {
            let r = run_scalar_ref(&program, [0; 32], budget);
            let f = run_fused(&program, [0; 32], budget);
            if let Err(e) =
                diff(&format!("{label} (fused, budget {budget})"), r, f)
            {
                panic!("{e}");
            }
        }
    }
}

/// The real workload: LeNet-5*-shaped model end-to-end, reference vs
/// lowered, on the baseline and fully-extended cores.
#[test]
fn lowered_matches_reference_on_lenet() {
    let spec = lenet_shaped(77);
    let mut rng = Rng::new(999);
    let input = Builder::random_input(&spec, &mut rng);
    for v in VARIANTS {
        let c = compile(&spec, v).unwrap();
        // reference path, via the raw machine
        let mut m = marvel::compiler::make_sim(&c).unwrap();
        marvel::compiler::load_input(&mut m, &c, &input).unwrap();
        let ref_stats = m.run_reference(1 << 33, &mut NopHook).unwrap();
        let ref_out =
            marvel::compiler::read_output(&m, &c, spec.output_elems()).unwrap();
        // lowered path, via the normal entry point
        let (low_out, low_stats) =
            execute_compiled(&c, &spec, &input, 1 << 33, &mut NopHook).unwrap();
        assert_eq!(low_stats, ref_stats, "{} RunStats", v.name);
        assert_eq!(low_out, ref_out, "{} outputs", v.name);
    }
}
