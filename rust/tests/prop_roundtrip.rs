//! The central end-to-end property: for ANY model graph the generator can
//! produce, compiling for every processor variant and running on the ISS
//! yields bit-identical outputs to the native reference executor — i.e. the
//! codegen templates, the Chess-style rewrite passes, and the zol lowering
//! are all semantics-preserving, including saturation/rounding edge cases.

use marvel::compiler::{compile, execute_compiled};
use marvel::isa::decode::decode;
use marvel::isa::encode::encode;
use marvel::models::synth::{random_net, Builder};
use marvel::refexec;
use marvel::sim::{NopHook, Sim, V0, VARIANTS};
use marvel::util::proptest::check;

#[test]
fn prop_random_nets_all_variants_match_reference() {
    check("compile→simulate ≡ refexec (all variants)", 60, |rng| {
        let spec = random_net(rng);
        let input = Builder::random_input(&spec, rng);
        let want = refexec::run(&spec, &input)
            .map_err(|e| format!("refexec: {e}"))?;
        for v in VARIANTS {
            let c = compile(&spec, v)
                .map_err(|e| format!("compile {} {}: {e}", spec.name, v.name))?;
            let (got, _) =
                execute_compiled(&c, &spec, &input, 1 << 33, &mut NopHook)
                    .map_err(|e| format!("run {} {}: {e}", spec.name, v.name))?;
            if got != want {
                return Err(format!(
                    "{} on {}: mismatch\n got: {:?}\nwant: {:?}\nlayers: {:?}",
                    spec.name,
                    v.name,
                    got,
                    want,
                    spec.layers.iter().map(|l| l.op_name()).collect::<Vec<_>>()
                ));
            }
        }
        Ok(())
    });
}

#[test]
fn prop_arbitrary_feature_masks_match_reference() {
    // Beyond the paper's cumulative ladder: ANY of the 16 extension
    // combinations (the ablation cores) must stay semantics-preserving.
    check("compile→simulate ≡ refexec (random masks)", 40, |rng| {
        let spec = random_net(rng);
        let input = Builder::random_input(&spec, rng);
        let want = refexec::run(&spec, &input)
            .map_err(|e| format!("refexec: {e}"))?;
        let v = marvel::sim::Variant {
            name: "mask",
            mac: rng.bool(),
            add2i: rng.bool(),
            fusedmac: rng.bool(),
            zol: rng.bool(),
            xwin: 0,
        };
        let c = compile(&spec, v).map_err(|e| format!("{e}"))?;
        let (got, _) = execute_compiled(&c, &spec, &input, 1 << 33, &mut NopHook)
            .map_err(|e| format!("{e}"))?;
        if got != want {
            return Err(format!(
                "mask mac={} add2i={} fusedmac={} zol={}: {got:?} != {want:?}",
                v.mac, v.add2i, v.fusedmac, v.zol
            ));
        }
        Ok(())
    });
}

#[test]
fn prop_variant_ladder_monotone_cycles() {
    // v0 ≥ v1 ≥ v2 ≥ v3 ≥ v4 in cycles: each extension only removes work.
    check("cycle counts decrease along the variant ladder", 15, |rng| {
        let spec = random_net(rng);
        let input = Builder::random_input(&spec, rng);
        let mut prev = u64::MAX;
        for v in VARIANTS {
            let c = compile(&spec, v).map_err(|e| format!("{e}"))?;
            let (_, stats) =
                execute_compiled(&c, &spec, &input, 1 << 33, &mut NopHook)
                    .map_err(|e| format!("{e}"))?;
            if stats.cycles > prev {
                return Err(format!(
                    "{}: {} cycles {} > previous {}",
                    spec.name, v.name, stats.cycles, prev
                ));
            }
            prev = stats.cycles;
        }
        Ok(())
    });
}

#[test]
fn prop_machine_code_words_reload_identically() {
    // The encoded PM image decodes back to the same program (assembler and
    // Sim::load agree with Sim::from_instrs).
    check("words → decode ≡ instrs", 20, |rng| {
        let spec = random_net(rng);
        let variant = *rng.choice(&VARIANTS);
        let c = compile(&spec, variant).map_err(|e| format!("{e}"))?;
        for (i, (instr, &word)) in
            c.instrs().iter().zip(c.words().iter()).enumerate()
        {
            let back = decode(word).map_err(|e| format!("word {i}: {e}"))?;
            if back != *instr {
                return Err(format!("word {i}: {back:?} != {instr:?}"));
            }
            if encode(&back) != word {
                return Err(format!("word {i}: re-encode mismatch"));
            }
        }
        // and a Sim::load of the words must run to the same output
        let input = Builder::random_input(&spec, rng);
        let want = refexec::run(&spec, &input).map_err(|e| format!("{e}"))?;
        let mut sim = Sim::load(variant, c.words(), c.plan.dm_size as usize)
            .map_err(|e| format!("{e}"))?;
        sim.mem
            .write_block(c.plan.weights_base, &c.plan.weights_image)
            .map_err(|e| format!("weights: {e:?}"))?;
        let bytes: Vec<u8> = input.iter().map(|&v| v as i8 as u8).collect();
        sim.mem
            .write_block(c.plan.input_addr, &bytes)
            .map_err(|e| format!("input: {e:?}"))?;
        sim.run(1 << 33, &mut NopHook).map_err(|e| format!("{e}"))?;
        let got = sim
            .mem
            .read_i8s(c.plan.output_addr, spec.output_elems())
            .map_err(|e| format!("output: {e:?}"))?;
        if got != want {
            return Err(format!("reloaded run mismatch: {got:?} vs {want:?}"));
        }
        Ok(())
    });
}

#[test]
fn prop_v0_code_never_contains_custom_instrs() {
    check("v0 binaries are pure RV32IM", 25, |rng| {
        let spec = random_net(rng);
        let c = compile(&spec, V0).map_err(|e| format!("{e}"))?;
        for (i, instr) in c.instrs().iter().enumerate() {
            if instr.is_custom() {
                return Err(format!("custom instr at {i}: {instr}"));
            }
        }
        Ok(())
    });
}

#[test]
fn prop_v4_code_never_larger_than_v0() {
    // Fusion + zol shrink the program (Table 10's PM column trend).
    check("pm(v4) <= pm(v0)", 25, |rng| {
        let spec = random_net(rng);
        let c0 = compile(&spec, V0).map_err(|e| format!("{e}"))?;
        let c4 = compile(&spec, marvel::sim::V4).map_err(|e| format!("{e}"))?;
        if c4.pm_bytes() > c0.pm_bytes() {
            return Err(format!(
                "v4 PM {} > v0 PM {}",
                c4.pm_bytes(),
                c0.pm_bytes()
            ));
        }
        Ok(())
    });
}

#[test]
fn prop_simulator_fuzz_random_words_never_panic() {
    // Arbitrary (mostly illegal) words must produce errors, not panics, and
    // legal-but-wild programs must stop at a fault or the watchdog.
    check("ISS is total over random programs", 200, |rng| {
        let n = rng.range_usize(1, 40);
        let words: Vec<u32> = (0..n).map(|_| rng.next_u32()).collect();
        if let Ok(mut sim) = Sim::load(marvel::sim::V4, &words, 4096) {
            let _ = sim.run(10_000, &mut NopHook);
        }
        Ok(())
    });
}

#[test]
fn prop_random_instruction_sequences_respect_watchdog() {
    use marvel::isa::random_instr;
    check("decoded random programs terminate or fault", 200, |rng| {
        let n = rng.range_usize(1, 60);
        let instrs: Vec<_> = (0..n).map(|_| random_instr(rng)).collect();
        let mut sim = match Sim::from_instrs(marvel::sim::V4, instrs, 1 << 16) {
            Ok(s) => s,
            Err(_) => return Ok(()),
        };
        let _ = sim.run(50_000, &mut NopHook); // must not hang or panic
        Ok(())
    });
}
