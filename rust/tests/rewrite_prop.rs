//! Properties of the spec-driven rewrite engine (DESIGN.md §17): for any
//! generated model, any ladder variant and any window-slot mask, the
//! rewritten program (a) survives the full encode → decode → disasm
//! round-trip and (b) computes exactly what the unrewritten reference
//! does.  This is the fuzzed counterpart of the fixed-pattern unit tests
//! in `compiler/rewrite` and the generic-vs-legacy differential.

use marvel::compiler::{compile, execute_compiled};
use marvel::isa::decode::decode;
use marvel::isa::disasm::disasm;
use marvel::isa::encode::encode;
use marvel::isa::Instr;
use marvel::models::synth::{random_net, Builder};
use marvel::refexec;
use marvel::sim::{NopHook, Variant, VARIANTS};
use marvel::util::proptest::check;

/// A random (base, window-mask) core: every ladder rung × every subset of
/// the mined spec pool.
fn random_variant(rng: &mut marvel::util::rng::Rng) -> Variant {
    let base = *rng.choice(&VARIANTS);
    let mask = (rng.next_u32() & ((1 << marvel::fusion::N_WINDOW) - 1)) as u8;
    Variant::with_window(base, mask).expect("in-pool mask")
}

#[test]
fn prop_rewritten_programs_roundtrip_and_match_reference() {
    check("rewrite → encode → decode → disasm; output ≡ refexec", 50, |rng| {
        let spec = random_net(rng);
        let v = random_variant(rng);
        let c = compile(&spec, v)
            .map_err(|e| format!("compile {} {}: {e}", spec.name, v.name))?;

        // every rewritten word must decode back to the same instruction,
        // re-encode to the same word, and have a total disassembly
        for (i, (instr, &word)) in
            c.instrs().iter().zip(c.words().iter()).enumerate()
        {
            let back = decode(word)
                .map_err(|e| format!("{}: word {i}: {e}", v.name))?;
            if back != *instr {
                return Err(format!(
                    "{}: word {i}: decode {back:?} != {instr:?}",
                    v.name
                ));
            }
            if encode(&back) != word {
                return Err(format!("{}: word {i}: re-encode mismatch", v.name));
            }
            if disasm(instr).is_empty() {
                return Err(format!("{}: word {i}: empty disasm", v.name));
            }
        }

        // rewritten ≡ unrewritten: the mined core computes the reference
        let input = Builder::random_input(&spec, rng);
        let want =
            refexec::run(&spec, &input).map_err(|e| format!("refexec: {e}"))?;
        let (got, _) = execute_compiled(&c, &spec, &input, 1 << 33, &mut NopHook)
            .map_err(|e| format!("run {} {}: {e}", spec.name, v.name))?;
        if got != want {
            return Err(format!(
                "{} on {}: {got:?} != {want:?}",
                spec.name, v.name
            ));
        }
        Ok(())
    });
}

#[test]
fn prop_rewrites_emit_only_supported_instructions() {
    // The rewrite engine may only emit what the target core implements:
    // no Custom slot outside the mask, no fused ops beyond the ladder.
    check("rewritten streams respect the variant's ISA", 50, |rng| {
        let spec = random_net(rng);
        let v = random_variant(rng);
        let c = compile(&spec, v).map_err(|e| format!("{e}"))?;
        for (i, instr) in c.instrs().iter().enumerate() {
            let legal = match instr {
                Instr::Custom { .. }
                | Instr::Mac
                | Instr::Add2i { .. }
                | Instr::FusedMac { .. } => v.supports(instr),
                _ => true,
            };
            if !legal {
                return Err(format!(
                    "{}: instr {i} {instr:?} not supported by {}",
                    spec.name, v.name
                ));
            }
            if let Instr::Custom { idx, .. } = instr {
                if usize::from(*idx) >= marvel::fusion::N_WINDOW {
                    return Err(format!("custom idx {idx} out of pool"));
                }
            }
        }
        Ok(())
    });
}

#[test]
fn prop_window_mask_never_regresses_cycles() {
    // Enabling mined slots can only remove work: cycles(v+xM) <= cycles(v)
    // and the full-mask core still matches the reference.
    check("window slots are pure wins", 20, |rng| {
        let spec = random_net(rng);
        let base = *rng.choice(&VARIANTS);
        let full = ((1u32 << marvel::fusion::N_WINDOW) - 1) as u8;
        let mined = Variant::with_window(base, full).expect("full mask");
        let input = Builder::random_input(&spec, rng);
        let want =
            refexec::run(&spec, &input).map_err(|e| format!("refexec: {e}"))?;

        let cb = compile(&spec, base).map_err(|e| format!("{e}"))?;
        let (_, sb) = execute_compiled(&cb, &spec, &input, 1 << 33, &mut NopHook)
            .map_err(|e| format!("{e}"))?;
        let cm = compile(&spec, mined).map_err(|e| format!("{e}"))?;
        let (got, sm) =
            execute_compiled(&cm, &spec, &input, 1 << 33, &mut NopHook)
                .map_err(|e| format!("{e}"))?;
        if got != want {
            return Err(format!("{}: {got:?} != {want:?}", mined.name));
        }
        if sm.cycles > sb.cycles {
            return Err(format!(
                "{}: {} cycles > {} {} cycles",
                mined.name, sm.cycles, base.name, sb.cycles
            ));
        }
        Ok(())
    });
}
