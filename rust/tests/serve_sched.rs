//! Scheduler-subsystem contract tests (DESIGN.md §14):
//!
//! 1. **FIFO bit-identity** — `--policy fifo` serves the exec-conformance
//!    model zoo bit-identically to the offline `run_descs_local`
//!    reference, on the local *and* the shard backend: the scheduler
//!    refactor moved requests between queues, never bytes.
//! 2. **Starvation freedom** — under a 10:1 two-tenant skew with the
//!    chatty tenant's whole backlog queued first, DRR serves the quiet
//!    tenant inside the first few batches (its delay is bounded by its
//!    round-robin share), while FIFO makes it ride behind the entire
//!    flood.  The batch sequence numbers make the bound exact and
//!    timing-independent.
//! 3. **Admission control** — a full per-model queue answers tickets with
//!    a structured error (never a panic, never a hang) while admitted
//!    neighbors and the *other* tenant keep serving.
//! 4. **Deadline scheduling** — with a no-deadline flood queued first,
//!    EDF pulls later-arriving deadline-carrying requests into the
//!    earliest batches, while FIFO parks them behind the whole flood.
//!    Batch sequence numbers make the comparison exact and
//!    timing-independent (the wall-clock goodput version of this claim
//!    lives in benches/bench_overload.rs).
//! 5. **Fault containment** — exec-site chaos within the retry budget is
//!    invisible (replies bit-identical to a fault-free run); past the
//!    budget the faulted job answers its ticket with a structured
//!    `ServeError` while every neighbor is served and shutdown drains —
//!    no panic, no hang.
//!
//! Like `tests/shard.rs`, the process-spawning case uses the real
//! `marvel` binary (`CARGO_BIN_EXE_marvel`) and synthetic models, so no
//! artifacts directory is needed.

use std::path::{Path, PathBuf};
use std::time::Duration;

use marvel::compiler::{pack_input, CompileCache};
use marvel::models::synth::{tiny_conv_net, Builder};
use marvel::sim::chaos::{self, FaultPlan};
use marvel::sim::exec::{Executor, LocalExec, ShardExec};
use marvel::sim::serve::{build_serve_models, model_key, Server, Ticket};
use marvel::sim::shard::{self, run_descs_local, JobDesc, ShardPool,
                         WorkerCmd};
use marvel::sim::{PolicyKind, Reply, ReqMeta, ServeError, ServeOptions, V0,
                  V4};
use marvel::util::rng::Rng;

fn artifacts() -> &'static Path {
    Path::new("artifacts")
}

fn zoo() -> Vec<String> {
    ["synth:tiny:3", "synth:lenet:5", "synth:residual:7"]
        .map(String::from)
        .to_vec()
}

/// Deterministic per-zoo-model job descriptions (mirrors the conformance
/// suite's interleaved zoo).
fn zoo_descs(n_inputs: usize) -> Vec<JobDesc> {
    let mut hyd = shard::Hydrator::new(artifacts());
    let mut out = Vec::new();
    for (mi, model) in zoo().iter().enumerate() {
        let spec = marvel::models::resolve(artifacts(), model).unwrap();
        let mut rng = Rng::new(900 + mi as u64);
        for v in [V0, V4] {
            let (c, _) = hyd.hydrate(model, v.name).unwrap();
            for _ in 0..n_inputs {
                let input = Builder::random_input(&spec, &mut rng);
                let packed = pack_input(&input).unwrap();
                out.push(shard::desc_for(model, &c, &packed, 1 << 33));
            }
        }
    }
    out
}

fn shard_exec(workers: usize) -> Box<dyn Executor> {
    let cmd = WorkerCmd {
        program: PathBuf::from(env!("CARGO_BIN_EXE_marvel")),
        envs: Vec::new(),
        args: vec![
            "shard-worker".to_string(),
            "--artifacts".to_string(),
            "artifacts".to_string(),
        ],
    };
    Box::new(ShardExec::from_pool(ShardPool::spawn(&cmd, workers).unwrap(), workers))
}

/// Invariant 1: FIFO replies are bit-identical to the offline reference
/// on every backend — and so are DRR's, since policies move requests
/// between batches, never change their bytes.
#[test]
fn fifo_and_drr_replies_match_offline_reference_on_both_backends() {
    let descs = zoo_descs(2);
    let reference = run_descs_local(artifacts(), &descs, 0);

    for bname in ["local:2", "shard:2"] {
        for policy in [PolicyKind::Fifo, PolicyKind::Drr] {
            let cache = CompileCache::new();
            let units = build_serve_models(
                artifacts(), &zoo(), &[V0, V4], &cache,
            )
            .unwrap();
            let opts = ServeOptions {
                max_batch: 8,
                policy,
                ..ServeOptions::default()
            }
            .fixed_window(Duration::from_millis(100));
            let exec: Box<dyn Executor> = if bname == "shard:2" {
                shard_exec(2)
            } else {
                Box::new(LocalExec::new(artifacts(), 2))
            };
            let (server, client) = Server::start(units, opts, exec);
            let tickets: Vec<Ticket> = descs
                .iter()
                .map(|d| {
                    client
                        .submit(&model_key(&d.model, &d.variant), d.input.clone())
                        .unwrap()
                })
                .collect();
            for (i, t) in tickets.into_iter().enumerate() {
                let r = t.wait().unwrap();
                let want = reference[i].as_ref().unwrap();
                assert_eq!(
                    r.output, want.output,
                    "{bname} {policy} request {i}: logits diverged"
                );
                assert_eq!(
                    r.stats, want.stats,
                    "{bname} {policy} request {i}: stats diverged"
                );
            }
            drop(client);
            let report = server.join();
            assert!(report.batches >= 1);
            let served: u64 =
                report.slo.rows.iter().map(|r| r.served).sum();
            assert_eq!(served as usize, descs.len(), "{bname} {policy}");
        }
    }
}

/// Drive the skew scenario: queue `chatty_n` chatty requests, then
/// `quiet_n` quiet ones (carrying `quiet_meta` — a deadline here turns
/// the skew into the EDF scenario), all inside one long collection
/// window, and return each tenant's highest batch sequence number.
fn skew_batch_seqs(
    policy: PolicyKind,
    chatty_n: usize,
    quiet_n: usize,
    quiet_meta: ReqMeta,
) -> (u64, u64, u64) {
    let cache = CompileCache::new();
    let units = build_serve_models(
        artifacts(),
        &["synth:lenet:5".to_string(), "synth:tiny:3".to_string()],
        &[V4],
        &cache,
    )
    .unwrap();
    // The chatty tenant floods with the *expensive* model: batch 1 (all
    // chatty — its flood is submitted first) executes for orders of
    // magnitude longer than the remaining submissions take to queue, so
    // by batch 2 the whole arrival sequence is in the queues and batch
    // composition is exactly the policy's choice, not a timing accident.
    let chatty_key = model_key("synth:lenet:5", "v4");
    let quiet_key = model_key("synth:tiny:3", "v4");
    let chatty_in = marvel::models::synth::lenet_shaped(5).input_elems();
    let quiet_in = tiny_conv_net(3).input_elems();
    let opts = ServeOptions {
        max_batch: 8,
        queue_cap: 1 << 12,
        policy,
        ..ServeOptions::default()
    }
    // One long window, so the flood queues behind batch 1 rather than
    // trickling into many tiny batches.
    .fixed_window(Duration::from_millis(500));
    let (server, client) =
        Server::start(units, opts, Box::new(LocalExec::new(artifacts(), 2)));

    let mut tickets = Vec::new();
    for _ in 0..chatty_n {
        tickets.push((false, client.submit(&chatty_key, vec![0; chatty_in]).unwrap()));
    }
    for _ in 0..quiet_n {
        tickets.push((
            true,
            client
                .submit_with(&quiet_key, vec![1; quiet_in], quiet_meta)
                .unwrap(),
        ));
    }
    let (mut chatty_max, mut quiet_max) = (0u64, 0u64);
    for (quiet, t) in tickets {
        let r = t.wait().unwrap();
        if quiet {
            quiet_max = quiet_max.max(r.batch_seq);
        } else {
            chatty_max = chatty_max.max(r.batch_seq);
        }
    }
    drop(client);
    let report = server.join();
    (quiet_max, chatty_max, report.batches)
}

/// Invariant 2: DRR bounds the quiet tenant's completion by its
/// round-robin share — under a 10:1 skew queued chatty-first, the quiet
/// tenant's last reply rides an early batch, while FIFO parks it behind
/// the whole flood.  (Batch numbers, not wall-clock, so the bound is
/// exact: with max_batch 8 over 2 active queues DRR gives each tenant 4
/// slots per batch — 8 quiet requests fit within batches 2..=3, the
/// bound below adds one batch of slack for queueing raciness.)
#[test]
fn drr_does_not_starve_the_low_rate_tenant() {
    // 80 chatty + 8 quiet ≈ 10:1, max_batch 8 -> ≥ 11 total batches.
    let (quiet_drr, chatty_drr, batches_drr) =
        skew_batch_seqs(PolicyKind::Drr, 80, 8, ReqMeta::default());
    assert!(
        quiet_drr <= 4,
        "drr: quiet tenant must finish within its first batches \
         (finished at batch {quiet_drr} of {batches_drr})"
    );
    assert!(
        chatty_drr > quiet_drr,
        "drr: the flood keeps running after the quiet tenant is done"
    );

    let (quiet_fifo, _, batches_fifo) =
        skew_batch_seqs(PolicyKind::Fifo, 80, 8, ReqMeta::default());
    assert!(
        quiet_fifo >= batches_fifo.saturating_sub(1),
        "fifo control: quiet queued last must finish in the last batches \
         (finished at batch {quiet_fifo} of {batches_fifo})"
    );
    assert!(
        quiet_drr < quiet_fifo,
        "drr ({quiet_drr}) must beat fifo ({quiet_fifo}) for the \
         quiet tenant under skew"
    );
}

/// Invariant 4: the same 10:1 skew with the quiet tenant carrying
/// deadlines.  EDF orders queue heads by deadline (no-deadline work
/// sorts last), so the quiet requests ride the earliest post-flood
/// batches; FIFO keeps them parked behind the entire backlog.  The
/// deadline is generous (minutes) so admission shedding never triggers —
/// this pins down *ordering*, and leaves wall-clock attainment to
/// benches/bench_overload.rs.
#[test]
fn edf_serves_deadline_requests_ahead_of_the_flood() {
    let meta = ReqMeta {
        deadline: Some(Duration::from_secs(120)),
        priority: 5,
    };
    let (quiet_edf, chatty_edf, batches_edf) =
        skew_batch_seqs(PolicyKind::Edf, 80, 8, meta);
    assert!(
        quiet_edf <= 4,
        "edf: deadline-carrying requests must ride the earliest batches \
         (finished at batch {quiet_edf} of {batches_edf})"
    );
    assert!(
        chatty_edf > quiet_edf,
        "edf: the no-deadline flood keeps draining after the deadline \
         work is done"
    );

    let (quiet_fifo, _, batches_fifo) =
        skew_batch_seqs(PolicyKind::Fifo, 80, 8, meta);
    assert!(
        quiet_fifo >= batches_fifo.saturating_sub(1),
        "fifo control: deadline requests queued last drain last \
         (finished at batch {quiet_fifo} of {batches_fifo})"
    );
    assert!(
        quiet_edf < quiet_fifo,
        "edf ({quiet_edf}) must beat fifo ({quiet_fifo}) for \
         deadline-carrying requests under skew"
    );
}

/// Run the same 4 single-tenant requests through a (possibly
/// chaos-wrapped) dispatcher; returns each ticket's outcome plus how many
/// jobs the shutdown report counted as errored.
fn serve_four_with_chaos(
    plan: Option<&str>,
) -> (Vec<Result<Reply, ServeError>>, u64) {
    let n_in = tiny_conv_net(3).input_elems();
    let key = model_key("synth:tiny:3", "v4");
    let cache = CompileCache::new();
    let units = build_serve_models(
        artifacts(),
        &["synth:tiny:3".to_string()],
        &[V4],
        &cache,
    )
    .unwrap();
    let opts = ServeOptions { max_batch: 8, ..ServeOptions::default() }
        .fixed_window(Duration::from_millis(200));
    let exec: Box<dyn Executor> = Box::new(LocalExec::new(artifacts(), 1));
    let exec = match plan {
        Some(p) => chaos::wrap(exec, Some(&FaultPlan::parse(p).unwrap())),
        None => exec,
    };
    let (server, client) = Server::start(units, opts, exec);
    let tickets: Vec<Ticket> = (0..4)
        .map(|i| client.submit(&key, vec![i as u8; n_in]).unwrap())
        .collect();
    let results: Vec<_> =
        tickets.into_iter().map(Ticket::wait_detailed).collect();
    drop(client);
    let report = server.join();
    let errored = report.slo.rows.iter().map(|r| r.errored).sum();
    (results, errored)
}

/// Invariant 5a: a chaos plan *within* [`chaos::CHAOS_EXEC_RETRIES`] is
/// invisible through the dispatcher — every ticket resolves with logits
/// bit-identical to a fault-free run's.
#[test]
fn exec_chaos_within_budget_is_invisible_through_the_dispatcher() {
    let (clean, clean_errored) = serve_four_with_chaos(None);
    assert_eq!(clean_errored, 0);
    let (healed, healed_errored) =
        serve_four_with_chaos(Some("transient@1x2,delay@2:5"));
    assert_eq!(healed_errored, 0, "in-budget chaos must heal silently");
    for (i, (a, b)) in clean.iter().zip(&healed).enumerate() {
        let (a, b) = (a.as_ref().unwrap(), b.as_ref().unwrap());
        assert_eq!(a.output, b.output, "request {i}: logits diverged");
        assert_eq!(a.stats, b.stats, "request {i}: stats diverged");
    }
}

/// Invariant 5b: a fault past the retry budget surfaces as a structured
/// `ServeError` on exactly the faulted job's ticket — kind `"exec"`,
/// message naming the exhausted budget — while every other ticket is
/// served and shutdown drains (no ticket hangs, no panic).
#[test]
fn exec_chaos_past_budget_answers_with_structured_serve_errors() {
    let (results, errored) = serve_four_with_chaos(Some("transient@0x99"));
    let failures: Vec<&ServeError> =
        results.iter().filter_map(|r| r.as_ref().err()).collect();
    assert_eq!(failures.len(), 1, "exactly the faulted job fails");
    assert_eq!(errored, 1, "the report counts it as errored, not served");
    let e = failures[0];
    assert_eq!(e.kind, "exec");
    assert!(e.msg.contains("retry budget exhausted"), "{e}");
    assert!(e.msg.contains("chaos"), "{e}");
    assert_eq!(results.iter().filter(|r| r.is_ok()).count(), 3);
}

/// Invariant 3: one tenant's flood hitting its queue cap sheds *that*
/// tenant's overflow with a structured ticket error; the other tenant's
/// admission and service are untouched.
#[test]
fn queue_cap_sheds_only_the_flooding_tenant() {
    let spec = tiny_conv_net(3);
    let n_in = spec.input_elems();
    let cache = CompileCache::new();
    let units = build_serve_models(
        artifacts(),
        &["synth:tiny:3".to_string()],
        &[V0, V4],
        &cache,
    )
    .unwrap();
    let chatty_key = model_key("synth:tiny:3", "v0");
    let quiet_key = model_key("synth:tiny:3", "v4");
    let opts = ServeOptions {
        max_batch: 64,
        queue_cap: 3,
        policy: PolicyKind::Drr,
        ..ServeOptions::default()
    }
    .fixed_window(Duration::from_millis(400));
    let (server, client) =
        Server::start(units, opts, Box::new(LocalExec::new(artifacts(), 1)));

    let chatty: Vec<Ticket> = (0..9)
        .map(|_| client.submit(&chatty_key, vec![0; n_in]).unwrap())
        .collect();
    let quiet: Vec<Ticket> = (0..2)
        .map(|_| client.submit(&quiet_key, vec![1; n_in]).unwrap())
        .collect();

    let chatty_results: Vec<_> = chatty.into_iter().map(Ticket::wait).collect();
    let served = chatty_results.iter().filter(|r| r.is_ok()).count();
    assert_eq!(served, 3, "cap 3 admits exactly 3 of the 9-flood");
    for r in &chatty_results {
        if let Err(e) = r {
            let msg = e.to_string();
            assert!(msg.contains("admission rejected"), "{msg}");
            assert!(msg.contains(&chatty_key), "{msg}");
        }
    }
    // The quiet tenant is fully served despite the neighbor's shed flood.
    for t in quiet {
        t.wait().expect("quiet tenant must be unaffected by the flood");
    }
    drop(client);
    let report = server.join();
    let chatty_row = report
        .slo
        .rows
        .iter()
        .find(|r| r.key == chatty_key)
        .expect("chatty row");
    assert_eq!((chatty_row.served, chatty_row.rejected), (3, 6));
    let quiet_row = report
        .slo
        .rows
        .iter()
        .find(|r| r.key == quiet_key)
        .expect("quiet row");
    assert_eq!((quiet_row.served, quiet_row.rejected), (2, 0));
}
