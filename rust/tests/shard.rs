//! Shard/serve layer contract tests (DESIGN.md §12):
//!
//! 1. **Wire fidelity** — property round-trip of serialized `Job`
//!    descriptions and results (random payloads, both the Ok and Err arm).
//! 2. **Differential** — a 2+-process sharded model-zoo sweep produces
//!    bit-identical logits and `RunStats` to the in-process engine, at the
//!    raw job level (`ShardPool::run` vs `run_descs_local`) and at the
//!    flow level (`run_flows` on `ShardExec` vs on `LocalExec`).
//! 3. **Failure model** — a worker death re-dispatches its jobs to
//!    survivors (results still complete and correct), a dead worker slot
//!    respawns so even a 1-worker pool survives a mid-sweep kill, and
//!    losing every worker (respawn budget included) propagates as a
//!    panic, mirroring the in-process contract.
//! 4. **Serving** — the async batching front answers with the same bytes
//!    the offline engine produces.
//!
//! The process-spawning tests use the real `marvel` binary via
//! `CARGO_BIN_EXE_marvel` and synthetic models (`synth:<kind>:<seed>`), so
//! they need no artifacts directory.

use std::path::{Path, PathBuf};

use marvel::compiler::CompileCache;
use marvel::coordinator::experiments::run_flows;
use marvel::coordinator::FlowOptions;
use marvel::sim::exec::{LocalExec, ShardExec};
use marvel::sim::shard::{
    self, desc_for, encode_job, encode_result, parse_line, run_descs_local,
    JobDesc, Msg, ShardPool, WorkerCmd,
};
use marvel::sim::{JobOutput, RunStats, SimError, V0, V4};
use marvel::util::proptest::check;
use marvel::util::rng::Rng;

fn marvel_worker_cmd() -> WorkerCmd {
    WorkerCmd {
        program: PathBuf::from(env!("CARGO_BIN_EXE_marvel")),
        envs: Vec::new(),
        args: vec![
            "shard-worker".to_string(),
            "--artifacts".to_string(),
            "artifacts".to_string(),
        ],
    }
}

/// A small zoo of deterministic synthetic models.
fn zoo() -> Vec<String> {
    ["synth:tiny:3", "synth:tiny:4", "synth:lenet:5", "synth:residual:7"]
        .map(String::from)
        .to_vec()
}

/// Deterministic job descriptions for `model` × variants × `n_inputs`,
/// hydrated through the same path the worker uses.
fn descs_for_zoo(models: &[String], n_inputs: usize) -> Vec<JobDesc> {
    let artifacts = Path::new("artifacts");
    let mut hyd = shard::Hydrator::new(artifacts);
    let mut descs = Vec::new();
    for (mi, model) in models.iter().enumerate() {
        let spec = marvel::models::resolve(artifacts, model).unwrap();
        let mut rng = Rng::new(1000 + mi as u64);
        for v in [V0, V4] {
            let (c, _) = hyd.hydrate(model, v.name).unwrap();
            for _ in 0..n_inputs {
                let input = marvel::models::synth::Builder::random_input(
                    &spec, &mut rng,
                );
                let packed = marvel::compiler::pack_input(&input).unwrap();
                descs.push(desc_for(model, &c, &packed, 1 << 33));
            }
        }
    }
    descs
}

// ---------------------------------------------------------------------------
// 1. Wire fidelity
// ---------------------------------------------------------------------------

#[test]
fn prop_wire_job_roundtrip() {
    check("job line roundtrips", 300, |rng| {
        let d = JobDesc {
            model: format!("synth:tiny:{}", rng.int_in(0, 1 << 20)),
            variant: ["v0", "v1", "v2", "v3", "v4"]
                [rng.range_usize(0, 5)]
            .to_string(),
            input: (0..rng.range_usize(0, 64))
                .map(|_| rng.next_u32() as u8)
                .collect(),
            max_instrs: rng.next_u64() % (1 << 53),
            program_fp: rng.next_u64(),
            base_dm_fp: rng.next_u64(),
        };
        let seq = rng.next_u64() % (1 << 50);
        let line = encode_job(seq, &d);
        if line.contains('\n') {
            return Err(format!("job line contains newline: {line:?}"));
        }
        match parse_line(&line) {
            Ok(Msg::Job { seq: s, desc }) if s == seq && desc == d => Ok(()),
            other => Err(format!("roundtrip failed: {other:?}\nwant {d:?}")),
        }
    });
}

#[test]
fn prop_wire_result_roundtrip() {
    check("result line roundtrips", 300, |rng| {
        let r: Result<JobOutput, String> = if rng.bool() {
            Ok(JobOutput {
                output: (0..rng.range_usize(0, 32))
                    .map(|_| rng.next_u32() as i32)
                    .collect(),
                stats: RunStats {
                    instrs: rng.next_u64() % (1 << 53),
                    cycles: rng.next_u64() % (1 << 53),
                },
            })
        } else {
            // error strings with JSON-hostile characters
            Err(format!(
                "fault \"at\" pc {:#x}\n\tunicode: café\\",
                rng.next_u32()
            ))
        };
        let seq = rng.next_u64() % (1 << 50);
        let line = encode_result(seq, &r);
        if line.contains('\n') {
            return Err(format!("result line contains newline: {line:?}"));
        }
        match parse_line(&line) {
            Ok(Msg::Done { seq: s, result }) if s == seq && result == r => {
                Ok(())
            }
            other => Err(format!("roundtrip failed: {other:?}\nwant {r:?}")),
        }
    });
}

// ---------------------------------------------------------------------------
// 2. Differentials: sharded ≡ in-process, bit for bit
// ---------------------------------------------------------------------------

/// In-process worker_loop (no subprocess): every result a worker would
/// stream back equals the local engine's, including SimError cases.
#[test]
fn worker_loop_matches_local_engine() {
    let artifacts = Path::new("artifacts");
    let mut descs = descs_for_zoo(&zoo()[..2], 2);
    // A failing job: absurdly low watchdog -> Watchdog error on both paths.
    let mut poison_budget = descs[0].clone();
    poison_budget.max_instrs = 1;
    descs.push(poison_budget);
    // A hydration failure: unknown model.
    let mut unknown = descs[0].clone();
    unknown.model = "synth:nope:1".into();
    descs.push(unknown);

    let mut feed = String::new();
    for (i, d) in descs.iter().enumerate() {
        feed.push_str(&encode_job(i as u64, d));
        feed.push('\n');
    }
    let mut out = Vec::new();
    shard::worker_loop(artifacts, std::io::Cursor::new(feed), &mut out)
        .unwrap();

    let local = run_descs_local(artifacts, &descs, 0);
    let text = String::from_utf8(out).unwrap();
    let mut results: Vec<Option<Result<JobOutput, String>>> =
        vec![None; descs.len()];
    let mut saw_ready = false;
    for line in text.lines() {
        match parse_line(line).unwrap() {
            Msg::Ready => saw_ready = true,
            Msg::Done { seq, result } => results[seq as usize] = Some(result),
            Msg::Job { .. } => panic!("worker emitted a job line"),
        }
    }
    assert!(saw_ready, "worker must handshake");
    for (i, (wire, local)) in results.iter().zip(&local).enumerate() {
        let wire = wire.as_ref().expect("result for every job");
        match (wire, local) {
            (Ok(w), Ok(l)) => {
                assert_eq!(w, l, "job {i}: wire != local engine")
            }
            (Err(_), Err(_)) => {}
            (w, l) => panic!("job {i}: wire {w:?} vs local {l:?}"),
        }
    }
    // the two injected failures really failed, with the right flavors
    let n = descs.len();
    assert!(matches!(&local[n - 2], Err(SimError::Watchdog { .. })));
    assert!(results[n - 2].as_ref().unwrap().is_err());
    assert!(results[n - 1]
        .as_ref()
        .unwrap()
        .as_ref()
        .unwrap_err()
        .contains("synth:nope"));
}

/// THE acceptance differential: a real 2-process sharded sweep over the
/// model zoo is bit-identical (logits and RunStats) to the in-process
/// engine, job by job.
#[test]
fn two_process_shard_sweep_bit_identical_to_in_process() {
    let artifacts = Path::new("artifacts");
    let descs = descs_for_zoo(&zoo(), 2);
    let local = run_descs_local(artifacts, &descs, 0);

    let mut pool = ShardPool::spawn(&marvel_worker_cmd(), 2).unwrap();
    let sharded = pool.run(&descs);
    assert_eq!(sharded.len(), local.len());
    for (i, (s, l)) in sharded.iter().zip(&local).enumerate() {
        match (s, l) {
            (Ok(s), Ok(l)) => {
                assert_eq!(s.output, l.output, "job {i}: logits diverged");
                assert_eq!(s.stats, l.stats, "job {i}: RunStats diverged");
            }
            (s, l) => panic!("job {i}: sharded {s:?} vs local {l:?}"),
        }
    }

    // Workers stay warm across batches: a second run on the same pool
    // must also be identical (hydration caches are per-process state).
    let again = pool.run(&descs);
    for (i, (a, l)) in again.iter().zip(&local).enumerate() {
        assert_eq!(
            a.as_ref().unwrap(),
            l.as_ref().unwrap(),
            "job {i}: second batch diverged"
        );
    }
}

/// Flow-level differential: `run_flows` on a `ShardExec` backend ≡ the
/// same sweep on `LocalExec`, on verification outcome and every
/// per-variant metric — the acceptance contract of the one
/// executor-driven entry point.
#[test]
fn sharded_flows_match_cached_flows() {
    let artifacts = Path::new("artifacts");
    let models = zoo()[..3].to_vec();
    let opts = FlowOptions {
        n_inputs: 2,
        variants: vec![V0, V4],
        ..FlowOptions::default()
    };
    let cache = CompileCache::new();
    let mut local_exec = LocalExec::new(artifacts, 0);
    let local =
        run_flows(artifacts, &models, &opts, &cache, &mut local_exec)
            .unwrap();
    let mut shard_exec = ShardExec::from_pool(
        ShardPool::spawn(&marvel_worker_cmd(), 3).unwrap(),
        3,
    );
    let sharded =
        run_flows(artifacts, &models, &opts, &cache, &mut shard_exec)
            .unwrap();

    assert_eq!(local.len(), sharded.len());
    for (l, s) in local.iter().zip(&sharded) {
        assert_eq!(l.model, s.model);
        assert!(l.verified_golden, "{}: local flow must verify", l.model);
        assert!(s.verified_golden, "{}: sharded flow must verify", s.model);
        assert_eq!(l.metrics.len(), s.metrics.len());
        for (lm, sm) in l.metrics.iter().zip(&s.metrics) {
            assert_eq!(lm.variant, sm.variant, "{}", l.model);
            assert_eq!(lm.instrs, sm.instrs, "{}", l.model);
            assert_eq!(lm.cycles, sm.cycles, "{}", l.model);
            assert_eq!(lm.pm_bytes, sm.pm_bytes, "{}", l.model);
            assert_eq!(lm.dm_bytes, sm.dm_bytes, "{}", l.model);
            assert_eq!(
                lm.speedup.to_bits(),
                sm.speedup.to_bits(),
                "{}",
                l.model
            );
        }
    }
}

// ---------------------------------------------------------------------------
// 3. Failure model
// ---------------------------------------------------------------------------

/// Degenerate pool (one worker) is still correct and ordered — the
/// sequential baseline of the partitioning.
#[test]
fn single_worker_pool_matches_local() {
    let descs = descs_for_zoo(&zoo()[..2], 2);
    let local = run_descs_local(Path::new("artifacts"), &descs, 0);
    let mut pool = ShardPool::spawn(&marvel_worker_cmd(), 1).unwrap();
    let r = pool.run(&descs);
    for (i, (a, l)) in r.iter().zip(&local).enumerate() {
        assert_eq!(a.as_ref().unwrap(), l.as_ref().unwrap(), "job {i}");
    }
}

/// A pool whose every worker dies (a stub that exits on the first job)
/// must panic — the process-level mirror of the in-process worker-panic
/// propagation.
#[test]
fn total_worker_loss_propagates_as_panic() {
    let cmd = WorkerCmd {
        program: PathBuf::from("/bin/sh"),
        envs: Vec::new(),
        args: vec![
            "-c".to_string(),
            // Handshake like a worker, then die on the first job line.
            "echo '{\"type\":\"ready\",\"version\":\"stub\"}'; read line; \
             exit 1"
                .to_string(),
        ],
    };
    let descs = descs_for_zoo(&zoo()[..1], 1);
    let mut pool = ShardPool::spawn(&cmd, 2).unwrap();
    let r = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
        pool.run(&descs)
    }));
    assert!(r.is_err(), "losing every worker must panic the caller");
}

/// Mixed pool: one real worker, one stub that dies on its first job.
/// The stub's jobs must be re-dispatched to the real worker and the full
/// result set must match the in-process engine.
#[test]
fn mixed_pool_death_still_completes_batch() {
    let real = marvel_worker_cmd();
    let descs = descs_for_zoo(&zoo()[..2], 2);
    let local = run_descs_local(Path::new("artifacts"), &descs, 0);

    // ShardPool spawns every worker from one cmd, so build the mix via a
    // sh trampoline: worker index comes from a file-based turnstile — the
    // first spawn becomes the dying stub, later spawns exec the real
    // worker.
    let dir = std::env::temp_dir().join(format!(
        "marvel-shard-test-{}",
        std::process::id()
    ));
    std::fs::create_dir_all(&dir).unwrap();
    let flag = dir.join("first");
    let script = format!(
        "if mkdir {f} 2>/dev/null; then \
           echo '{{\"type\":\"ready\",\"version\":\"stub\"}}'; \
           read line; exit 1; \
         else exec {prog} shard-worker --artifacts artifacts; fi",
        f = flag.display(),
        prog = real.program.display(),
    );
    let cmd = WorkerCmd {
        program: PathBuf::from("/bin/sh"),
        envs: Vec::new(),
        args: vec!["-c".to_string(), script],
    };
    let mut pool = ShardPool::spawn(&cmd, 2).unwrap();
    let r = pool.run(&descs);
    let _ = std::fs::remove_dir_all(&dir);
    for (i, (a, l)) in r.iter().zip(&local).enumerate() {
        assert_eq!(
            a.as_ref().unwrap(),
            l.as_ref().unwrap(),
            "job {i} after worker death"
        );
    }
}

/// Auto-respawn: a 1-worker pool whose only worker is killed mid-sweep
/// (the stub dies after receiving its first job) must relaunch the slot
/// and still produce results bit-identical to the in-process engine.
/// Without respawn this configuration is fatal — the pool would panic on
/// total worker loss — so completion alone proves the relaunch, and
/// `respawns_used` pins it down.
#[test]
fn dead_worker_respawns_and_batch_completes() {
    let real = marvel_worker_cmd();
    let descs = descs_for_zoo(&zoo()[..2], 2);
    let local = run_descs_local(Path::new("artifacts"), &descs, 0);

    // File-based turnstile (one flag dir per test): the first spawn is a
    // stub that dies on its first job — the mid-sweep kill — and every
    // respawn execs the real worker.
    let dir = std::env::temp_dir().join(format!(
        "marvel-respawn-test-{}",
        std::process::id()
    ));
    std::fs::create_dir_all(&dir).unwrap();
    let flag = dir.join("first");
    let script = format!(
        "if mkdir {f} 2>/dev/null; then \
           echo '{{\"type\":\"ready\",\"version\":\"stub\"}}'; \
           read line; exit 1; \
         else exec {prog} shard-worker --artifacts artifacts; fi",
        f = flag.display(),
        prog = real.program.display(),
    );
    let cmd = WorkerCmd {
        program: PathBuf::from("/bin/sh"),
        envs: Vec::new(),
        args: vec!["-c".to_string(), script],
    };
    let mut pool = ShardPool::spawn(&cmd, 1).unwrap();
    let r = pool.run(&descs);
    let _ = std::fs::remove_dir_all(&dir);
    assert!(
        pool.respawns_used() >= 1,
        "the killed worker must have been relaunched"
    );
    assert_eq!(pool.live_workers(), 1);
    for (i, (a, l)) in r.iter().zip(&local).enumerate() {
        assert_eq!(
            a.as_ref().unwrap(),
            l.as_ref().unwrap(),
            "job {i} after worker respawn"
        );
    }
}

// ---------------------------------------------------------------------------
// 4. Serving front end-to-end (library level; the CLI line protocol has
//    its own unit tests and the CI smoke)
// ---------------------------------------------------------------------------

#[test]
fn serve_front_matches_offline_engine() {
    use marvel::sim::serve::{build_serve_models, model_key, Server};
    use marvel::sim::ServeOptions;

    let artifacts = Path::new("artifacts");
    let cache = CompileCache::new();
    let units = build_serve_models(
        artifacts,
        &zoo()[..2],
        &[V0, V4],
        &cache,
    )
    .unwrap();
    let (server, client) = Server::start(
        units,
        ServeOptions { max_batch: 16, ..ServeOptions::default() }
            .fixed_window(std::time::Duration::from_millis(100)),
        Box::new(LocalExec::new(artifacts, 2)),
    );

    // Mirror requests through the offline engine via descs.
    let descs = descs_for_zoo(&zoo()[..2], 2);
    let local = run_descs_local(artifacts, &descs, 0);
    let tickets: Vec<_> = descs
        .iter()
        .map(|d| {
            client
                .submit(&model_key(&d.model, &d.variant), d.input.clone())
                .unwrap()
        })
        .collect();
    let mut max_batch_seen = 0;
    for (i, t) in tickets.into_iter().enumerate() {
        let r = t.wait().unwrap();
        let l = local[i].as_ref().unwrap();
        assert_eq!(r.output, l.output, "request {i}: served logits diverged");
        assert_eq!(r.stats, l.stats, "request {i}: served stats diverged");
        max_batch_seen = max_batch_seen.max(r.batch_size);
    }
    assert!(
        max_batch_seen > 1,
        "concurrent submissions must share a batch (saw max {max_batch_seen})"
    );
    drop(client);
    server.join();
}
