//! Offline stand-in for the `anyhow` crate (same crate name, drop-in for
//! the subset this workspace uses — see `util` for the serde/proptest/
//! criterion equivalents).
//!
//! Implemented surface:
//! - [`Result<T>`] / [`Error`] with a context chain,
//! - [`Context`] on `Result<T, E: Into<Error>>` and `Option<T>`
//!   (`.context(..)` / `.with_context(|| ..)`),
//! - the [`anyhow!`], [`bail!`] and [`ensure!`] macros,
//! - `{e}` prints the outermost message, `{e:#}` the full chain joined
//!   with `": "`, `{e:?}` the message plus a `Caused by:` list.
//!
//! Not implemented (unused here): downcasting, backtraces, `Error::new`.

use std::fmt;

/// `Result<T, anyhow::Error>` with the same default type parameter trick.
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// An error plus the stack of human-readable contexts wrapped around it.
pub struct Error {
    /// Outermost context first; the last entry is the root cause.
    chain: Vec<String>,
}

impl Error {
    /// Build an error from a printable message.
    pub fn msg<M: fmt::Display>(message: M) -> Error {
        Error { chain: vec![message.to_string()] }
    }

    /// Wrap with an outer context (what `.context(..)` attaches).
    pub fn context<C: fmt::Display>(mut self, context: C) -> Error {
        self.chain.insert(0, context.to_string());
        self
    }

    /// The context chain, outermost first.
    pub fn chain(&self) -> impl Iterator<Item = &str> {
        self.chain.iter().map(String::as_str)
    }

    /// The innermost (root-cause) message.
    pub fn root_cause(&self) -> &str {
        self.chain.last().map(String::as_str).unwrap_or("")
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if f.alternate() {
            f.write_str(&self.chain.join(": "))
        } else {
            f.write_str(&self.chain[0])
        }
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.chain[0])?;
        if self.chain.len() > 1 {
            f.write_str("\n\nCaused by:")?;
            for (i, cause) in self.chain[1..].iter().enumerate() {
                write!(f, "\n    {i}: {cause}")?;
            }
        }
        Ok(())
    }
}

// Like the real anyhow, `Error` deliberately does NOT implement
// `std::error::Error` — that is what makes this blanket `From` legal.
impl<E> From<E> for Error
where
    E: std::error::Error + Send + Sync + 'static,
{
    fn from(e: E) -> Error {
        let mut chain = vec![e.to_string()];
        let mut src = e.source();
        while let Some(s) = src {
            chain.push(s.to_string());
            src = s.source();
        }
        Error { chain }
    }
}

/// `.context(..)` / `.with_context(|| ..)` on fallible values.
pub trait Context<T, E>: Sized {
    fn context<C>(self, context: C) -> Result<T, Error>
    where
        C: fmt::Display + Send + Sync + 'static;

    fn with_context<C, F>(self, f: F) -> Result<T, Error>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C;
}

impl<T, E> Context<T, E> for Result<T, E>
where
    E: Into<Error>,
{
    fn context<C>(self, context: C) -> Result<T, Error>
    where
        C: fmt::Display + Send + Sync + 'static,
    {
        self.map_err(|e| e.into().context(context))
    }

    fn with_context<C, F>(self, f: F) -> Result<T, Error>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C,
    {
        self.map_err(|e| e.into().context(f()))
    }
}

impl<T> Context<T, std::convert::Infallible> for Option<T> {
    fn context<C>(self, context: C) -> Result<T, Error>
    where
        C: fmt::Display + Send + Sync + 'static,
    {
        self.ok_or_else(|| Error::msg(context))
    }

    fn with_context<C, F>(self, f: F) -> Result<T, Error>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C,
    {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Construct an [`Error`] from a format string or printable value.
#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::Error::msg(format!($msg))
    };
    ($fmt:expr, $($arg:tt)*) => {
        $crate::Error::msg(format!($fmt, $($arg)*))
    };
    ($err:expr $(,)?) => {
        $crate::Error::msg($err)
    };
}

/// Return early with an error.
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::anyhow!($($arg)*))
    };
}

/// Return early with an error unless the condition holds.
#[macro_export]
macro_rules! ensure {
    ($cond:expr $(,)?) => {
        if !($cond) {
            return Err($crate::Error::msg(concat!(
                "condition failed: ",
                stringify!($cond)
            )));
        }
    };
    ($cond:expr, $($arg:tt)*) => {
        if !($cond) {
            return Err($crate::anyhow!($($arg)*));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_err() -> std::io::Error {
        std::io::Error::new(std::io::ErrorKind::NotFound, "missing file")
    }

    #[test]
    fn context_chain_formats() {
        let e: Error = Err::<(), _>(io_err())
            .context("reading config")
            .unwrap_err();
        assert_eq!(format!("{e}"), "reading config");
        assert_eq!(format!("{e:#}"), "reading config: missing file");
        let dbg = format!("{e:?}");
        assert!(dbg.contains("Caused by:"), "{dbg}");
    }

    #[test]
    fn option_context() {
        let v: Option<u32> = None;
        let e = v.context("missing value").unwrap_err();
        assert_eq!(format!("{e}"), "missing value");
        assert_eq!(Some(7).context("unused").unwrap(), 7);
    }

    #[test]
    fn macros() {
        fn inner(x: i32) -> Result<i32> {
            ensure!(x >= 0, "negative input {x}");
            if x == 0 {
                bail!("zero is not allowed");
            }
            Ok(x)
        }
        assert_eq!(inner(3).unwrap(), 3);
        assert_eq!(format!("{}", inner(-2).unwrap_err()), "negative input -2");
        assert_eq!(format!("{}", inner(0).unwrap_err()), "zero is not allowed");
        let n = 4;
        let e = anyhow!("count was {n}");
        assert_eq!(format!("{e}"), "count was 4");
    }

    #[test]
    fn question_mark_converts_std_errors() {
        fn read() -> Result<String> {
            let s = std::fs::read_to_string("/definitely/not/here/xyz")?;
            Ok(s)
        }
        assert!(read().is_err());
    }
}
