"""Shared measurement loading for tools/bench_gate.py and
tools/bench_trend.py — one definition of what a bench JSON line means,
so the gate and the trend dashboard can never disagree about the same
BENCH_*.json rows.

Each input file holds one JSON object per line (see
rust/benches/common.rs; BENCH_iss/BENCH_serve/BENCH_overload/
BENCH_extgen/BENCH_cluster all share the format):

    {"name": "...", "median_s": ..., "min_s": ..., "units_per_s": ...}
    {"name": "...", "p50_s": ..., "p95_s": ..., "p99_s": ...}
    {"name": "...", "goodput": ..., "met": ..., "total": ...}
"""

import json
from pathlib import Path

# (field, higher_is_better) per measurement kind, in probe order:
# `units_per_s` throughput rows, the overload bench's `goodput`
# deadline-attainment rows (a fraction in [0, 1], higher is better —
# legitimately 0.0 under an adversarial trace, hence the zero exemption
# in load()), the extsearch sweep's `speedup` rows (cycles vs the v0
# baseline, higher is better — `marvel extsearch --json`), and the serve
# bench's `p99_s` tail-latency rows (lower is better).
KINDS = (("units_per_s", True), ("goodput", True), ("speedup", True),
         ("p99_s", False))


def load(path: Path) -> dict[str, tuple[str, float]]:
    """name -> (kind, value) for every parseable line with a measurement.

    When a name repeats across invocations with the same kind, the best
    rep wins (max for throughput, min for latency); a repeat under a
    *different* kind replaces the entry (a renamed/retyped bench —
    consumers compare kinds before trusting a pair).
    """
    out: dict[str, tuple[str, float]] = {}
    if not path.exists():
        return out
    for line in path.read_text().splitlines():
        line = line.strip()
        if not line:
            continue
        try:
            row = json.loads(line)
        except json.JSONDecodeError:
            continue
        if "name" not in row:
            continue
        for field, higher_better in KINDS:
            v = row.get(field)
            ok_zero = field == "goodput"  # 0.0 goodput is a real datum
            if isinstance(v, (int, float)) and (v > 0 or (ok_zero and v >= 0)):
                if row["name"] in out and out[row["name"]][0] == field:
                    old = out[row["name"]][1]
                    v = max(v, old) if higher_better else min(v, old)
                out[row["name"]] = (field, v)
                break
    return out
