#!/usr/bin/env python3
"""Bench regression gate: compare a fresh bench JSON (BENCH_iss.json,
BENCH_serve.json, BENCH_cluster.json) against the previous run's uploaded artifact and fail on
a large regression.

Each input file holds one JSON object per line (see rust/benches/common.rs):

    {"name": "...", "median_s": ..., "min_s": ..., "units_per_s": ...}
    {"name": "...", "p50_s": ..., "p95_s": ..., "p99_s": ...}

Three measurement kinds are gated:

- `units_per_s` (throughput): higher is better; regression = current
  falling below (1 - max-drop) x previous.  The cluster scaling bench's
  jobs/s rows (`cluster/N jobs/H hosts`, BENCH_cluster.json) gate this
  way, one row per host count.
- `goodput` (the overload bench's deadline-attainment fraction): higher
  is better, same rule as throughput; a 0.0 baseline (the adversarial
  fifo trace) can only improve or hold.
- `p99_s` (tail latency, the serve bench's per-tenant rows): lower is
  better; regression = current rising above previous / (1 - drop), where
  drop is `--max-drop-latency` when given (tail latency is noisier than
  median-derived throughput) else `--max-drop`.

Only measurements present in BOTH files with the SAME kind are compared
(names change as benches evolve; new/renamed entries just pass).
Missing/empty previous file is a pass — the first run on a branch has no
baseline.  The ISS dispatch/lane rows (`iss/*/dispatch:{threaded,match}`,
`iss/v4/lanes:{1,4,8}`) enter the gate this way: `units_per_s` throughput
rows that pass as `new:` until a baseline artifact carries them, then are
held to the same tolerance as every other throughput row.

Usage: bench_gate.py PREV.json CURRENT.json [--max-drop 0.15]
"""

import argparse
import sys
from pathlib import Path

from bench_common import KINDS, load


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("prev", type=Path)
    ap.add_argument("current", type=Path)
    ap.add_argument("--max-drop", type=float, default=0.15,
                    help="fractional goodness drop that fails the gate")
    ap.add_argument("--max-drop-latency", type=float, default=None,
                    help="override for lower-is-better (p99_s) rows — tail "
                         "latency is noisier than median throughput; "
                         "defaults to --max-drop")
    args = ap.parse_args()

    prev = load(args.prev)
    cur = load(args.current)
    if not prev:
        print(f"bench gate: no baseline at {args.prev} — pass (first run)")
        return 0
    if not cur:
        print(f"bench gate: FAIL — no measurements in {args.current}")
        return 1

    failures = []
    compared = 0
    for name, (kind, was) in sorted(prev.items()):
        got = cur.get(name)
        if got is None or got[0] != kind:
            print(f"  skip (gone):   {name}")
            continue
        now = got[1]
        compared += 1
        # Normalize to a higher-is-better "goodness" ratio.  A zero
        # baseline (possible only for `goodput` rows) cannot regress:
        # any recovery is an improvement, staying at zero is parity.
        higher_better = dict(KINDS)[kind]
        if higher_better:
            ratio = (now / was) if was > 0 else (
                float("inf") if now > 0 else 1.0)
        else:
            ratio = was / now
        max_drop = args.max_drop if higher_better else (
            args.max_drop_latency
            if args.max_drop_latency is not None else args.max_drop)
        status = "ok" if ratio >= 1.0 - max_drop else "REGRESSED"
        print(f"  {status:9s} {name}: {was:.3e} -> {now:.3e} {kind} "
              f"({(ratio - 1.0) * 100.0:+.1f}% goodness, "
              f"tolerance {max_drop:.0%})")
        if status != "ok":
            failures.append(name)
    for name in sorted(set(cur) - set(prev)):
        print(f"  new:           {name}")

    if failures:
        print(f"bench gate: FAIL — {len(failures)}/{compared} measurements "
              f"regressed past tolerance: {', '.join(failures)}")
        return 1
    print(f"bench gate: pass ({compared} measurements within tolerance)")
    return 0


if __name__ == "__main__":
    sys.exit(main())
