#!/usr/bin/env python3
"""Bench regression gate: compare a fresh BENCH_iss.json against the
previous run's uploaded artifact and fail on a large throughput drop.

Each input file holds one JSON object per line (see rust/benches/common.rs):

    {"name": "...", "median_s": ..., "min_s": ..., "mean_s": ..., "units_per_s": ...}

Only measurements present in BOTH files with a `units_per_s` field are
compared (names change as benches evolve; new/renamed entries just pass).
A measurement regresses if current throughput falls below
(1 - max-drop) x previous.  Missing/empty previous file is a pass — the
first run on a branch has no baseline.

Usage: bench_gate.py PREV.json CURRENT.json [--max-drop 0.15]
"""

import argparse
import json
import sys
from pathlib import Path


def load(path: Path) -> dict[str, float]:
    """name -> units_per_s for every parseable line with a throughput."""
    out: dict[str, float] = {}
    if not path.exists():
        return out
    for line in path.read_text().splitlines():
        line = line.strip()
        if not line:
            continue
        try:
            row = json.loads(line)
        except json.JSONDecodeError:
            continue
        ups = row.get("units_per_s")
        if isinstance(ups, (int, float)) and ups > 0 and "name" in row:
            # Keep the best rep if a name repeats across bench invocations.
            out[row["name"]] = max(ups, out.get(row["name"], 0.0))
    return out


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("prev", type=Path)
    ap.add_argument("current", type=Path)
    ap.add_argument("--max-drop", type=float, default=0.15,
                    help="fractional throughput drop that fails the gate")
    args = ap.parse_args()

    prev = load(args.prev)
    cur = load(args.current)
    if not prev:
        print(f"bench gate: no baseline at {args.prev} — pass (first run)")
        return 0
    if not cur:
        print(f"bench gate: FAIL — no measurements in {args.current}")
        return 1

    failures = []
    compared = 0
    for name, was in sorted(prev.items()):
        now = cur.get(name)
        if now is None:
            print(f"  skip (gone):   {name}")
            continue
        compared += 1
        ratio = now / was
        status = "ok" if ratio >= 1.0 - args.max_drop else "REGRESSED"
        print(f"  {status:9s} {name}: {was:.3e} -> {now:.3e} units/s "
              f"({(ratio - 1.0) * 100.0:+.1f}%)")
        if status != "ok":
            failures.append(name)
    for name in sorted(set(cur) - set(prev)):
        print(f"  new:           {name}")

    if failures:
        print(f"bench gate: FAIL — {len(failures)}/{compared} measurements "
              f"dropped more than {args.max_drop:.0%}: {', '.join(failures)}")
        return 1
    print(f"bench gate: pass ({compared} measurements within "
          f"{args.max_drop:.0%})")
    return 0


if __name__ == "__main__":
    sys.exit(main())
