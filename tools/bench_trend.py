#!/usr/bin/env python3
"""Bench trend dashboard: render bench JSON measurements (BENCH_iss.json,
BENCH_serve.json, BENCH_cluster.json) across the last N CI runs into a small markdown/ASCII
report (ROADMAP item — the trajectory view next to tools/bench_gate.py's
pairwise gate).

Each input file holds one JSON object per line (see rust/benches/common.rs):

    {"name": "...", "median_s": ..., "min_s": ..., "units_per_s": ...}
    {"name": "...", "p50_s": ..., "p95_s": ..., "p99_s": ...}

Three measurement kinds are tracked: `units_per_s` throughput rows
(higher is better), the overload bench's `goodput` deadline-attainment
fractions (higher is better), and the serve bench's `p99_s` tail-latency
rows (lower is better, rendered in ms and marked `↓`).  Files are given OLDEST FIRST;
the last file is the current run.  For every measurement name seen
anywhere, the dashboard shows a sparkline across the runs (missing runs
render as a gap), the oldest and newest values, and the total change.
Unparseable or empty files are tolerated — CI artifact retrieval is
best-effort.

Usage: bench_trend.py OLDEST.json [...] CURRENT.json [--out BENCH_trend.md]
"""

import argparse
import sys
from pathlib import Path

from bench_common import load

SPARK = "▁▂▃▄▅▆▇█"
GAP = "·"


def sparkline(values: list[float | None]) -> str:
    """Unicode sparkline, normalized per measurement; None renders a gap."""
    present = [v for v in values if v is not None]
    if not present:
        return GAP * len(values)
    lo, hi = min(present), max(present)
    span = hi - lo
    chars = []
    for v in values:
        if v is None:
            chars.append(GAP)
        elif span <= 0:
            chars.append(SPARK[-1])
        else:
            idx = int((v - lo) / span * (len(SPARK) - 1))
            chars.append(SPARK[idx])
    return "".join(chars)


def fmt(v: float | None, kind: str = "units_per_s") -> str:
    if v is None:
        return "-"
    if kind == "p99_s":
        return f"{v * 1e3:.3f}ms"
    if kind == "goodput":
        return f"{v:.2f}"
    if v >= 1e9:
        return f"{v / 1e9:.2f}G"
    if v >= 1e6:
        return f"{v / 1e6:.2f}M"
    if v >= 1e3:
        return f"{v / 1e3:.2f}k"
    return f"{v:.1f}"


def render(runs: list[dict[str, tuple[str, float]]],
           labels: list[str]) -> str:
    names = sorted({n for r in runs for n in r})
    lines = [
        f"# Bench trend — {len(runs)} runs (oldest → newest)",
        "",
        "Per-measurement trajectory across the last CI artifacts; "
        "sparkline is normalized per row.  Throughput rows "
        "(`units_per_s`) are higher-is-better; `↓` rows are serve p99 "
        "tail latency, lower-is-better.",
        "",
        "| measurement | trend | oldest | newest | Δ |",
        "|---|---|---:|---:|---:|",
    ]
    for name in names:
        entries = [r.get(name) for r in runs]
        kind = next(e for e in entries if e is not None)[0]
        # Ignore same-named rows whose kind changed (a renamed bench).
        values = [
            e[1] if e is not None and e[0] == kind else None
            for e in entries
        ]
        first = next(v for v in values if v is not None)
        # "newest" is strictly the current (last) run: a renamed/removed
        # measurement shows a gap, not its stale last-seen value.
        current = values[-1]
        delta = (
            f"{(current / first - 1.0) * 100.0:+.1f}%"
            if current is not None and first > 0
            else "-"
        )
        mark = " ↓" if kind == "p99_s" else ""
        lines.append(
            f"| `{name}`{mark} | `{sparkline(values)}` | {fmt(first, kind)} "
            f"| {fmt(current, kind)} | {delta} |"
        )
    if not names:
        lines.append("| _no measurements found_ | | | | |")
    lines += ["", f"Runs: {', '.join(labels)}", ""]
    return "\n".join(lines)


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("files", type=Path, nargs="+",
                    help="BENCH json files, oldest first, current last")
    ap.add_argument("--out", type=Path, default=None,
                    help="also write the dashboard to this markdown file")
    args = ap.parse_args()

    runs, labels = [], []
    for path in args.files:
        data = load(path)
        if not data:
            print(f"bench trend: skipping {path} (no measurements)",
                  file=sys.stderr)
            continue
        runs.append(data)
        labels.append(str(path))
    if not runs:
        print("bench trend: no usable inputs — nothing to render",
              file=sys.stderr)
        return 0  # best-effort: an empty history is not a CI failure

    text = render(runs, labels)
    print(text)
    if args.out:
        args.out.write_text(text)
        print(f"bench trend: written to {args.out}", file=sys.stderr)
    return 0


if __name__ == "__main__":
    sys.exit(main())
